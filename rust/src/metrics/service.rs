//! Serve-daemon counters: lock-free request/byte totals plus a
//! per-archive shard-touch histogram, snapshotted on demand into the
//! plain [`ServeStats`] value that crosses the wire for `stats`
//! requests. The hot path only does relaxed atomic increments; all
//! aggregation happens at snapshot time.

use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters owned by a running server. One instance per daemon,
/// shared (via `Arc`) across connection handler threads.
#[derive(Debug)]
pub struct ServeMetrics {
    /// Requests received (any kind).
    pub requests: AtomicU64,
    /// Range requests answered with data.
    pub data_ok: AtomicU64,
    /// Range requests shed with `Busy`.
    pub busy: AtomicU64,
    /// Requests answered with an error frame.
    pub errors: AtomicU64,
    /// Decoded particle bytes returned to clients.
    pub bytes_served: AtomicU64,
    /// Region (box) requests answered with data.
    pub region_requests: AtomicU64,
    /// Timestep (temporal chain) requests answered with data.
    pub timestep_requests: AtomicU64,
    /// Shards the spatial index pruned from region requests.
    pub shards_pruned: AtomicU64,
    /// Admission acquires that had to wait (blocked at least once)
    /// before a slot opened up.
    pub retries: AtomicU64,
    /// Shards recovered by the salvage fallback when a served archive
    /// opened without an intact footer.
    pub salvaged_shards: AtomicU64,
    /// Connections closed by a graceful drain after their in-flight
    /// request completed.
    pub drained_connections: AtomicU64,
    /// Archive names, parallel to `shard_touches`.
    names: Vec<String>,
    /// Shards fetched (cache hit or decode) per archive.
    shard_touches: Vec<AtomicU64>,
}

impl ServeMetrics {
    /// Fresh zeroed counters for the given served-archive names.
    pub fn new(names: Vec<String>) -> Self {
        let shard_touches = names.iter().map(|_| AtomicU64::new(0)).collect();
        ServeMetrics {
            requests: AtomicU64::new(0),
            data_ok: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            bytes_served: AtomicU64::new(0),
            region_requests: AtomicU64::new(0),
            timestep_requests: AtomicU64::new(0),
            shards_pruned: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            salvaged_shards: AtomicU64::new(0),
            drained_connections: AtomicU64::new(0),
            names,
            shard_touches,
        }
    }

    /// Count `n` shard touches against archive `archive_id` (its index
    /// in the served list). Out-of-range ids are ignored — the server
    /// resolves names before counting, so this only guards bugs.
    pub fn touch_shards(&self, archive_id: usize, n: u64) {
        if let Some(c) = self.shard_touches.get(archive_id) {
            c.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Materialize the counters (plus cache and admission figures the
    /// server layers in) into one wire-serializable value.
    pub fn snapshot(
        &self,
        cache: CacheFigures,
        inflight: u64,
        inflight_high_water: u64,
    ) -> ServeStats {
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            data_ok: self.data_ok.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            bytes_served: self.bytes_served.load(Ordering::Relaxed),
            region_requests: self.region_requests.load(Ordering::Relaxed),
            timestep_requests: self.timestep_requests.load(Ordering::Relaxed),
            shards_pruned: self.shards_pruned.load(Ordering::Relaxed),
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_coalesced: cache.coalesced,
            cache_evictions: cache.evictions,
            cache_entries: cache.entries,
            cache_bytes: cache.bytes,
            cache_cap_bytes: cache.cap_bytes,
            inflight,
            inflight_high_water,
            retries: self.retries.load(Ordering::Relaxed),
            salvaged_shards: self.salvaged_shards.load(Ordering::Relaxed),
            drained_connections: self.drained_connections.load(Ordering::Relaxed),
            archives: self
                .names
                .iter()
                .zip(&self.shard_touches)
                .map(|(n, t)| (n.clone(), t.load(Ordering::Relaxed)))
                .collect(),
        }
    }
}

/// Cache-side figures folded into a [`ServeStats`] snapshot (produced
/// by the serve shard cache; kept here so `metrics` does not depend on
/// `serve`).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheFigures {
    /// Lookups served from memory.
    pub hits: u64,
    /// Lookups that required a decode.
    pub misses: u64,
    /// Lookups that joined a concurrent in-flight decode (single-flight
    /// coalescing) instead of decoding again.
    pub coalesced: u64,
    /// Entries displaced by the weight bound.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
    /// Decoded bytes currently resident.
    pub bytes: u64,
    /// Configured weight bound in bytes.
    pub cap_bytes: u64,
}

/// Point-in-time server statistics, as answered to a `stats` request.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests received (any kind).
    pub requests: u64,
    /// Range requests answered with data.
    pub data_ok: u64,
    /// Range requests shed with `Busy`.
    pub busy: u64,
    /// Requests answered with an error frame.
    pub errors: u64,
    /// Decoded particle bytes returned to clients.
    pub bytes_served: u64,
    /// Region (box) requests answered with data.
    pub region_requests: u64,
    /// Timestep (temporal chain) requests answered with data.
    pub timestep_requests: u64,
    /// Shards spatial-index pruning skipped across all region requests.
    pub shards_pruned: u64,
    /// Shard-cache lookups served from memory.
    pub cache_hits: u64,
    /// Shard-cache lookups that required a decode.
    pub cache_misses: u64,
    /// Shard-cache lookups coalesced onto a concurrent in-flight decode.
    pub cache_coalesced: u64,
    /// Shard-cache entries displaced by the weight bound.
    pub cache_evictions: u64,
    /// Shard-cache entries currently resident.
    pub cache_entries: u64,
    /// Decoded bytes currently resident in the shard cache.
    pub cache_bytes: u64,
    /// Configured cache weight bound in bytes.
    pub cache_cap_bytes: u64,
    /// Range requests currently admitted and decoding.
    pub inflight: u64,
    /// Peak concurrent admitted requests over the server's lifetime.
    pub inflight_high_water: u64,
    /// Admission acquires that blocked at least once before admission.
    pub retries: u64,
    /// Shards recovered by the salvage fallback at archive-open time.
    pub salvaged_shards: u64,
    /// Connections closed by a graceful drain after finishing a request.
    pub drained_connections: u64,
    /// `(archive name, shards fetched)` per served archive.
    pub archives: Vec<(String, u64)>,
}

impl ServeStats {
    /// Render as stable `key: value` lines (what `nblc get --stats`
    /// prints and the CI smoke test greps).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("requests: {}\n", self.requests));
        s.push_str(&format!("data ok: {}\n", self.data_ok));
        s.push_str(&format!("busy: {}\n", self.busy));
        s.push_str(&format!("errors: {}\n", self.errors));
        s.push_str(&format!("bytes served: {}\n", self.bytes_served));
        s.push_str(&format!("region requests: {}\n", self.region_requests));
        s.push_str(&format!(
            "timestep requests: {}\n",
            self.timestep_requests
        ));
        s.push_str(&format!("shards pruned: {}\n", self.shards_pruned));
        s.push_str(&format!("cache hits: {}\n", self.cache_hits));
        s.push_str(&format!("cache misses: {}\n", self.cache_misses));
        s.push_str(&format!("cache coalesced: {}\n", self.cache_coalesced));
        s.push_str(&format!("cache evictions: {}\n", self.cache_evictions));
        s.push_str(&format!(
            "cache resident: {} entries, {} / {} bytes\n",
            self.cache_entries, self.cache_bytes, self.cache_cap_bytes
        ));
        s.push_str(&format!(
            "inflight: {} (high water {})\n",
            self.inflight, self.inflight_high_water
        ));
        s.push_str(&format!("retries: {}\n", self.retries));
        s.push_str(&format!("salvaged shards: {}\n", self.salvaged_shards));
        s.push_str(&format!(
            "drained connections: {}\n",
            self.drained_connections
        ));
        for (name, touches) in &self.archives {
            s.push_str(&format!("archive {name}: {touches} shard touches\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = ServeMetrics::new(vec!["a.nblc".into(), "b.nblc".into()]);
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.data_ok.fetch_add(3, Ordering::Relaxed);
        m.busy.fetch_add(1, Ordering::Relaxed);
        m.bytes_served.fetch_add(1024, Ordering::Relaxed);
        m.region_requests.fetch_add(2, Ordering::Relaxed);
        m.timestep_requests.fetch_add(8, Ordering::Relaxed);
        m.shards_pruned.fetch_add(14, Ordering::Relaxed);
        m.retries.fetch_add(4, Ordering::Relaxed);
        m.salvaged_shards.fetch_add(6, Ordering::Relaxed);
        m.drained_connections.fetch_add(2, Ordering::Relaxed);
        m.touch_shards(0, 4);
        m.touch_shards(1, 2);
        m.touch_shards(9, 7); // out of range: ignored
        let cache = CacheFigures {
            hits: 10,
            misses: 6,
            coalesced: 5,
            evictions: 2,
            entries: 4,
            bytes: 4096,
            cap_bytes: 1 << 20,
        };
        let s = m.snapshot(cache, 2, 3);
        assert_eq!(s.requests, 5);
        assert_eq!(s.data_ok, 3);
        assert_eq!(s.busy, 1);
        assert_eq!(s.errors, 0);
        assert_eq!(s.bytes_served, 1024);
        assert_eq!(s.region_requests, 2);
        assert_eq!(s.timestep_requests, 8);
        assert_eq!(s.shards_pruned, 14);
        assert_eq!(s.cache_hits, 10);
        assert_eq!(s.cache_coalesced, 5);
        assert_eq!(s.cache_evictions, 2);
        assert_eq!(s.inflight, 2);
        assert_eq!(s.inflight_high_water, 3);
        assert_eq!(s.retries, 4);
        assert_eq!(s.salvaged_shards, 6);
        assert_eq!(s.drained_connections, 2);
        assert_eq!(
            s.archives,
            vec![("a.nblc".to_string(), 4), ("b.nblc".to_string(), 2)]
        );
    }

    #[test]
    fn render_is_grepable() {
        let s = ServeStats {
            cache_hits: 12,
            region_requests: 3,
            timestep_requests: 6,
            shards_pruned: 21,
            retries: 5,
            salvaged_shards: 7,
            drained_connections: 1,
            archives: vec![("x.nblc".into(), 9)],
            ..Default::default()
        };
        let text = s.render();
        assert!(text.contains("cache hits: 12\n"));
        assert!(text.contains("region requests: 3\n"));
        assert!(text.contains("timestep requests: 6\n"));
        assert!(text.contains("shards pruned: 21\n"));
        assert!(text.contains("retries: 5\n"));
        assert!(text.contains("salvaged shards: 7\n"));
        assert!(text.contains("drained connections: 1\n"));
        assert!(text.contains("archive x.nblc: 9 shard touches\n"));
    }
}
