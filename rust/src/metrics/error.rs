//! Pointwise compression-error statistics (paper §III definitions):
//! absolute error, NRMSE = sqrt(Σe²/N)/R, and PSNR = −20·log10(NRMSE).

use crate::error::{Error, Result};
use crate::snapshot::Snapshot;
use crate::util::stats::value_range;

/// Error statistics between an original and a reconstructed field.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorStats {
    /// Maximum pointwise absolute error.
    pub max_err: f64,
    /// Mean absolute error.
    pub mean_err: f64,
    /// Normalised root-mean-square error (range-normalised).
    pub nrmse: f64,
    /// Peak signal-to-noise ratio in dB (−20·log10(NRMSE)).
    pub psnr: f64,
    /// Value range of the original data.
    pub range: f64,
}

impl ErrorStats {
    /// Compute over one field pair.
    pub fn compute(orig: &[f32], recon: &[f32]) -> Result<ErrorStats> {
        if orig.len() != recon.len() {
            return Err(Error::invalid("length mismatch in error stats"));
        }
        if orig.is_empty() {
            return Ok(ErrorStats::default());
        }
        let range = value_range(orig);
        let mut max_err = 0f64;
        let mut sum_err = 0f64;
        let mut sse = 0f64;
        for (&a, &b) in orig.iter().zip(recon.iter()) {
            let e = (a as f64 - b as f64).abs();
            max_err = max_err.max(e);
            sum_err += e;
            sse += e * e;
        }
        let n = orig.len() as f64;
        let rmse = (sse / n).sqrt();
        let nrmse = if range > 0.0 { rmse / range } else { 0.0 };
        let psnr = if nrmse > 0.0 {
            -20.0 * nrmse.log10()
        } else {
            f64::INFINITY
        };
        Ok(ErrorStats {
            max_err,
            mean_err: sum_err / n,
            nrmse,
            psnr,
            range,
        })
    }

    /// Aggregate PSNR over all six fields of a snapshot pair (each field
    /// range-normalised separately, then averaged in the error domain —
    /// how Z-checker reports multi-field data).
    pub fn snapshot_psnr(orig: &Snapshot, recon: &Snapshot) -> Result<f64> {
        if orig.len() != recon.len() {
            return Err(Error::invalid("snapshot length mismatch"));
        }
        let mut total_sq = 0f64;
        let mut total_n = 0usize;
        for f in 0..6 {
            let range = value_range(&orig.fields[f]);
            if range <= 0.0 {
                continue;
            }
            for (&a, &b) in orig.fields[f].iter().zip(recon.fields[f].iter()) {
                let e = (a as f64 - b as f64) / range;
                total_sq += e * e;
            }
            total_n += orig.len();
        }
        if total_n == 0 || total_sq == 0.0 {
            return Ok(f64::INFINITY);
        }
        let nrmse = (total_sq / total_n as f64).sqrt();
        Ok(-20.0 * nrmse.log10())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_is_infinite_psnr() {
        let xs = vec![1.0f32, 2.0, 3.0];
        let s = ErrorStats::compute(&xs, &xs).unwrap();
        assert_eq!(s.max_err, 0.0);
        assert!(s.psnr.is_infinite());
    }

    #[test]
    fn known_values() {
        let orig = vec![0.0f32, 1.0];
        let recon = vec![0.1f32, 0.9];
        let s = ErrorStats::compute(&orig, &recon).unwrap();
        assert!((s.max_err - 0.1).abs() < 1e-6);
        assert!((s.nrmse - 0.1).abs() < 1e-6);
        assert!((s.psnr - 20.0).abs() < 1e-3);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        assert!(ErrorStats::compute(&[1.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn psnr_improves_with_smaller_error() {
        let orig: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let coarse: Vec<f32> = orig.iter().map(|x| x + 1.0).collect();
        let fine: Vec<f32> = orig.iter().map(|x| x + 0.01).collect();
        let a = ErrorStats::compute(&orig, &coarse).unwrap();
        let b = ErrorStats::compute(&orig, &fine).unwrap();
        assert!(b.psnr > a.psnr);
    }
}
