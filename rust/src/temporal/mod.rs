//! Temporal keyframe+delta compression for multi-snapshot streams.
//!
//! The source paper scopes to single snapshots; this subsystem extends
//! the v3 archive into a time-series store. A stream archive holds `T`
//! timesteps of `n_p` particles each, laid out as consecutive global
//! particle slabs (timestep `t` owns particles `t·n_p .. (t+1)·n_p`),
//! so every existing decode path — full decode, `--particles` ranges,
//! salvage — keeps working on the *stored* representation. The footer's
//! temporal block ([`ArchiveTemporal`]) records what that
//! representation means: which steps are keyframes (stored snapshots)
//! and which are deltas (residuals against a velocity-extrapolated
//! prediction from the previous *decoded* step — see [`predictor`]),
//! plus per-step `dt` and the per-field bounds the decoder is entitled
//! to.
//!
//! [`ShardReader::decode_timestep`] is the seek path: it touches only
//! the shards of timestep `t`'s keyframe group (the keyframe at or
//! before `t` plus the deltas up to `t`), never the whole archive —
//! O(K) work for a keyframe interval of K, independent of `T`.
//!
//! Module layout: [`predictor`] holds the prediction/residual math,
//! [`chain`] the keyframe cadence and per-step bound derivation, and
//! this root the read path. The write path (the `nblc pipeline
//! --stream` rounds) lives in
//! [`crate::coordinator::pipeline::run_insitu_stream`].

pub mod chain;
pub mod predictor;

pub use chain::{delta_bounds, residual_quality, TemporalConfig, RESIDUAL_MARGIN};
pub use predictor::{predict, reconstruct, residual};

use crate::data::archive::{ShardReader, TemporalStep};
use crate::error::{Error, Result};
use crate::exec::ExecCtx;
use crate::snapshot::Snapshot;

/// Stream archives require order-preserving codecs: delta residuals are
/// particle-index-aligned, so a reordering codec's output cannot be
/// replayed against a prediction.
fn reject_reordered(reordered: bool) -> Result<()> {
    if reordered {
        return Err(Error::invalid(
            "temporal chain written with a reordering codec: delta residuals \
             are particle-index-aligned, so this archive cannot be replayed",
        ));
    }
    Ok(())
}

/// Result of [`ShardReader::decode_timestep`].
#[derive(Debug)]
pub struct DecodedTimestep {
    /// The fully reconstructed timestep (`n_p` particles, original
    /// particle order — stream archives require order-preserving
    /// codecs).
    pub snapshot: Snapshot,
    /// Shard records fetched and decoded — exactly the keyframe group's
    /// shards from the keyframe through `t`, proving the O(K) seek.
    pub shards_touched: usize,
    /// The keyframe timestep the reconstruction started from.
    pub keyframe: usize,
    /// The requested timestep.
    pub timestep: usize,
    /// First global particle index of the timestep's slab.
    pub particle_start: u64,
    /// One past the last global particle index of the slab.
    pub particle_end: u64,
}

impl ShardReader {
    /// Reconstruct timestep `t` of a stream archive, touching only its
    /// keyframe group: decode the keyframe at or before `t`, then
    /// replay predict → decode-residual → reconstruct for each delta
    /// step up to `t`. Errors on non-stream archives, out-of-range
    /// timesteps, and chains written with a reordering codec (delta
    /// residuals are particle-index-aligned, so reordering codecs are
    /// rejected at write time too).
    pub fn decode_timestep(&self, t: usize, ctx: &ExecCtx) -> Result<DecodedTimestep> {
        let factory = crate::compressors::registry::factory(self.spec())?;
        reject_reordered(factory().reorders())?;
        self.replay_chain(t, ctx, &|i, inner| {
            let bundle = self.read_shard(i)?;
            factory().decompress_with(inner, &bundle)
        })
    }

    /// [`Self::decode_timestep`] with the per-shard decode replaced by
    /// a caller hook — the serve daemon's cached path. `fetch(i)` must
    /// return shard `i` fully decoded; the LRU cache interposes there,
    /// so a hot keyframe group's shards decode once and serve many
    /// timestep requests (only the cheap predict/reconstruct replay
    /// runs per request). `reordered` is the codec's `reorders()` flag,
    /// resolved once at archive-open time like
    /// [`crate::data::archive::decode_shards_cached`]'s.
    pub fn decode_timestep_cached(
        &self,
        t: usize,
        ctx: &ExecCtx,
        reordered: bool,
        fetch: &(dyn Fn(usize) -> Result<std::sync::Arc<Snapshot>> + Sync),
    ) -> Result<DecodedTimestep> {
        reject_reordered(reordered)?;
        self.replay_chain(t, ctx, &|i, _inner| fetch(i).map(|p| (*p).clone()))
    }

    /// Shared chain replay: `decode(i, inner_ctx)` returns shard `i`
    /// decoded. Kept private so both entry points agree on validation
    /// and touch accounting.
    fn replay_chain(
        &self,
        t: usize,
        ctx: &ExecCtx,
        decode: &(dyn Fn(usize, &ExecCtx) -> Result<Snapshot> + Sync),
    ) -> Result<DecodedTimestep> {
        let tc = self
            .temporal()
            .ok_or_else(|| Error::invalid("archive has no temporal chain (not a stream archive)"))?;
        let k = tc.keyframe_for(t).ok_or_else(|| {
            Error::invalid(format!(
                "timestep {t} out of range: the chain holds {} steps",
                tc.steps.len()
            ))
        })?;
        let mut touched = 0usize;
        let mut cur = self.decode_step(&tc.steps[k], ctx, decode, &mut touched)?;
        for u in k + 1..=t {
            let step = &tc.steps[u];
            let raw = self.decode_step(step, ctx, decode, &mut touched)?;
            if raw.len() != cur.len() {
                return Err(Error::corrupt(format!(
                    "timestep {u} holds {} particles, timestep {} holds {}",
                    raw.len(),
                    u - 1,
                    cur.len()
                )));
            }
            let pred = predict(&cur, step.dt);
            cur = reconstruct(&pred, &raw, &step.bounds)?;
        }
        // The timestep's global particle slab: the chain parser
        // guarantees each step's shard range is non-empty and
        // contiguous in the table.
        let entries = &self.index().entries;
        let step = &tc.steps[t];
        let (lo, hi) = (
            entries[step.shard_lo as usize].start,
            entries[step.shard_hi as usize - 1].end,
        );
        Ok(DecodedTimestep {
            snapshot: cur,
            shards_touched: touched,
            keyframe: k,
            timestep: t,
            particle_start: lo,
            particle_end: hi,
        })
    }

    /// Decode one chain step's stored payload (keyframe snapshot or
    /// residual), shards fanned out over `ctx` and stitched in logical
    /// order.
    fn decode_step(
        &self,
        step: &TemporalStep,
        ctx: &ExecCtx,
        decode: &(dyn Fn(usize, &ExecCtx) -> Result<Snapshot> + Sync),
        touched: &mut usize,
    ) -> Result<Snapshot> {
        let shards: Vec<usize> = (step.shard_lo as usize..step.shard_hi as usize).collect();
        *touched += shards.len();
        let per_shard = (ctx.threads() / shards.len()).max(1);
        let inner = ExecCtx::with_threads(per_shard);
        let parts = ctx.try_par(&shards, |&i| {
            let part = decode(i, &inner)?;
            let e = &self.index().entries[i];
            if part.len() as u64 != e.end - e.start {
                return Err(Error::corrupt(format!(
                    "shard {i} decoded to {} particles, footer says {}",
                    part.len(),
                    e.end - e.start
                )));
            }
            Ok(part)
        })?;
        if parts.len() == 1 {
            Ok(parts.into_iter().next().unwrap())
        } else {
            Snapshot::concat(&parts)
        }
    }
}
