//! The velocity-extrapolation predictor and its residual codec.
//!
//! A delta timestep stores `r = fl32(x(t) - x̂(t))` where
//! `x̂(t) = fl32(x_dec(t-1) + v_dec(t-1)·dt)` — prediction always runs
//! off *decoded* data, so the encoder and the decoder compute the same
//! `x̂` bit for bit and quantization error never compounds along the
//! chain: at every timestep `|x_dec - x| ≤ eb_residual + f32 rounding`,
//! independent of how many delta steps precede it.
//!
//! Velocities use the identity predictor (`v̂(t) = v_dec(t-1)`), so
//! their residual is the per-step velocity change (`a·dt` scale for
//! leapfrog-evolved data) — small and highly compressible.
//!
//! All intermediate arithmetic is `f64`, rounded to `f32` exactly once
//! per value; this is what makes the predictor deterministic across
//! SIMD/scalar kernels and thread counts.

use crate::error::{Error, Result};
use crate::snapshot::{Snapshot, VEL_OFFSET};

/// Predict timestep `t` from the decoded timestep `t-1`: coordinates
/// extrapolate by `x + v·dt` (per axis, `f64` math, one rounding), and
/// velocities carry over unchanged.
pub fn predict(prev: &Snapshot, dt: f64) -> Snapshot {
    let mut fields: [Vec<f32>; 6] = Default::default();
    for axis in 0..VEL_OFFSET {
        let xs = &prev.fields[axis];
        let vs = &prev.fields[VEL_OFFSET + axis];
        fields[axis] = xs
            .iter()
            .zip(vs)
            .map(|(&x, &v)| (x as f64 + v as f64 * dt) as f32)
            .collect();
        fields[VEL_OFFSET + axis] = vs.clone();
    }
    Snapshot {
        name: prev.name.clone(),
        fields,
        box_size: prev.box_size,
        seed: prev.seed,
    }
}

/// The payload a delta timestep compresses: per-field residuals
/// `fl32(orig - pred)` for fields with a lossy bound, and the original
/// values verbatim for fields whose recorded bound is [`EXACT`] (the
/// passthrough marker — see [`super::chain::delta_bounds`]). The
/// decoder applies the same per-field rule from the footer's recorded
/// bounds, so the split is deterministic.
///
/// [`EXACT`]: crate::quality::EXACT
pub fn residual(orig: &Snapshot, pred: &Snapshot, bounds: &[f64; 6]) -> Result<Snapshot> {
    if orig.len() != pred.len() {
        return Err(Error::invalid(format!(
            "residual: timestep has {} particles, prediction has {}",
            orig.len(),
            pred.len()
        )));
    }
    let fields: [Vec<f32>; 6] = std::array::from_fn(|f| {
        if bounds[f] == crate::quality::EXACT {
            orig.fields[f].clone()
        } else {
            orig.fields[f]
                .iter()
                .zip(&pred.fields[f])
                .map(|(&o, &p)| (o as f64 - p as f64) as f32)
                .collect()
        }
    });
    Ok(Snapshot {
        name: orig.name.clone(),
        fields,
        box_size: orig.box_size,
        seed: orig.seed,
    })
}

/// Invert [`residual`] with the decoded residual: lossy fields add the
/// residual back onto the prediction (`fl32(pred + r_dec)`), passthrough
/// fields take the stored values verbatim.
pub fn reconstruct(pred: &Snapshot, res: &Snapshot, bounds: &[f64; 6]) -> Result<Snapshot> {
    if res.len() != pred.len() {
        return Err(Error::corrupt(format!(
            "reconstruct: residual decoded to {} particles, prediction has {}",
            res.len(),
            pred.len()
        )));
    }
    let fields: [Vec<f32>; 6] = std::array::from_fn(|f| {
        if bounds[f] == crate::quality::EXACT {
            res.fields[f].clone()
        } else {
            pred.fields[f]
                .iter()
                .zip(&res.fields[f])
                .map(|(&p, &r)| (p as f64 + r as f64) as f32)
                .collect()
        }
    });
    Ok(Snapshot {
        name: res.name.clone(),
        fields,
        box_size: res.box_size,
        seed: res.seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_md::{generate_md, MdConfig};

    fn snap(n: usize) -> Snapshot {
        generate_md(&MdConfig {
            n_particles: n,
            ..Default::default()
        })
    }

    #[test]
    fn predict_extrapolates_coords_and_keeps_velocities() {
        let s = snap(500);
        let p = predict(&s, 0.25);
        for i in 0..s.len() {
            for axis in 0..3 {
                let want =
                    (s.fields[axis][i] as f64 + s.fields[3 + axis][i] as f64 * 0.25) as f32;
                assert_eq!(p.fields[axis][i], want);
                assert_eq!(p.fields[3 + axis][i], s.fields[3 + axis][i]);
            }
        }
        // dt = 0 is the identity on every field.
        let id = predict(&s, 0.0);
        assert_eq!(id.fields, s.fields);
    }

    #[test]
    fn residual_reconstruct_is_exact_on_undamaged_residuals() {
        // With the residual passed through unquantized, reconstruction
        // differs from the original only by one f32 rounding per value.
        let s = snap(400);
        let prev = snap(400);
        let pred = predict(&prev, 0.1);
        let bounds = [1e-3; 6];
        let r = residual(&s, &pred, &bounds).unwrap();
        let back = reconstruct(&pred, &r, &bounds).unwrap();
        for f in 0..6 {
            for i in 0..s.len() {
                let got = back.fields[f][i] as f64;
                let want = s.fields[f][i] as f64;
                let tol = 2.0 * f32::EPSILON as f64 * want.abs().max(1.0);
                assert!((got - want).abs() <= tol, "field {f} particle {i}");
            }
        }
    }

    #[test]
    fn passthrough_fields_are_bit_exact() {
        let s = snap(300);
        let pred = predict(&snap(300), 0.1);
        // Field 0 passthrough, the rest lossy.
        let bounds = [0.0, 1e-3, 1e-3, 1e-3, 1e-3, 1e-3];
        let r = residual(&s, &pred, &bounds).unwrap();
        assert_eq!(r.fields[0], s.fields[0], "passthrough stores the original");
        let back = reconstruct(&pred, &r, &bounds).unwrap();
        assert_eq!(back.fields[0], s.fields[0]);
    }

    #[test]
    fn length_mismatches_are_typed_errors() {
        let a = snap(100);
        let b = snap(101);
        let bounds = [1e-3; 6];
        assert!(residual(&a, &b, &bounds).is_err());
        assert!(reconstruct(&a, &b, &bounds).is_err());
    }
}
