//! Chain planning: keyframe cadence and per-step residual bounds.
//!
//! A stream archive is a sequence of keyframe groups: timestep `t` is a
//! keyframe iff `t % K == 0` (K = `keyframe_interval`), and every delta
//! step between two keyframes is reconstructed by replaying predictions
//! from the nearest keyframe at or before it. K trades compression
//! against seek cost: larger K means more (smaller) delta steps per
//! group but up to K-1 replayed steps on a mid-chain
//! `decode_timestep`.
//!
//! ## Why the per-step bound holds without drift
//!
//! The footer records, per timestep and field, the bound the decoder is
//! entitled to: `|x_dec - x| ≤ b`. Keyframes get the quality's resolved
//! bound directly. Delta steps compress the residual at
//! [`RESIDUAL_MARGIN`]`·b` absolute, and since the residual is taken
//! against a prediction both sides compute from *decoded* data,
//! reconstruction error is `|r_dec - r|` plus two f32 roundings — the
//! margin absorbs the roundings, so `b` holds at every step no matter
//! how deep the chain. When `b` is so tight the margin cannot absorb
//! f32 rounding at the field's magnitude (or the bound resolved to
//! [`EXACT`] already), the step degrades that field to *passthrough*:
//! the original values are stored losslessly and the recorded bound is
//! [`EXACT`] — strictly better than promised, and the marker the
//! decoder keys the per-field split on.

use crate::error::{Error, Result};
use crate::quality::{ErrorBound, FieldStats, Quality, EXACT};
use crate::snapshot::FIELD_NAMES;

/// Fraction of the per-field resolved bound given to the residual
/// quantizer; the rest absorbs the two f32 roundings of the
/// predict/reconstruct round-trip (see the module doc).
pub const RESIDUAL_MARGIN: f64 = 0.75;

/// Rounding guard: a delta field needs `margin·b` comfortably above the
/// f32 ulp at the field's magnitude, or passthrough is safer.
const ROUNDING_GUARD: f64 = 8.0 * (f32::EPSILON as f64);

/// Stream-mode knobs (the `[temporal]` config section /
/// `--keyframe-every` flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TemporalConfig {
    /// Keyframe cadence K: timestep `t` is a keyframe iff `t % K == 0`.
    /// `1` means every timestep is a keyframe (no deltas).
    pub keyframe_interval: usize,
}

impl TemporalConfig {
    /// Validate the cadence (`1..=` [`MAX_SHARDS`]).
    ///
    /// [`MAX_SHARDS`]: crate::data::archive::MAX_SHARDS
    pub fn new(keyframe_interval: usize) -> Result<TemporalConfig> {
        if keyframe_interval == 0 {
            return Err(Error::invalid("keyframe interval must be at least 1"));
        }
        if keyframe_interval > crate::data::archive::MAX_SHARDS {
            return Err(Error::invalid(format!(
                "keyframe interval {keyframe_interval} is implausibly large"
            )));
        }
        Ok(TemporalConfig { keyframe_interval })
    }

    /// Whether timestep `t` starts a new keyframe group.
    pub fn is_keyframe(&self, t: usize) -> bool {
        t % self.keyframe_interval == 0
    }
}

impl Default for TemporalConfig {
    fn default() -> Self {
        TemporalConfig {
            keyframe_interval: 8,
        }
    }
}

/// Per-field bounds recorded in the footer for a *delta* step, given
/// the quality's bounds resolved against the original timestep
/// (`quality.resolve_fields(stats)`) and that timestep's field stats.
///
/// A field comes back either as its resolved bound (the full
/// reconstruction guarantee — the residual itself is quantized at
/// [`RESIDUAL_MARGIN`] of it) or as [`EXACT`], the passthrough marker:
/// the bound was already exact, or too tight for the margin to absorb
/// f32 rounding at the field's magnitude (`max |x|`).
pub fn delta_bounds(resolved: &[f64; 6], stats: &[FieldStats; 6]) -> [f64; 6] {
    std::array::from_fn(|f| {
        let b = resolved[f];
        if b == EXACT {
            return EXACT;
        }
        let max_abs = (stats[f].min.abs() as f64).max(stats[f].max.abs() as f64);
        if RESIDUAL_MARGIN * b <= ROUNDING_GUARD * max_abs {
            EXACT
        } else {
            b
        }
    })
}

/// The quality a delta step's residual snapshot is compressed under:
/// `Abs(RESIDUAL_MARGIN · b)` per lossy field, `Lossless` for
/// passthrough fields. The absolute override re-resolves against each
/// residual shard's own (small) value range, which is what makes delta
/// steps compress far smaller than keyframes on coherent streams.
pub fn residual_quality(step_bounds: &[f64; 6]) -> Quality {
    let mut q = Quality::new(ErrorBound::Lossless);
    for (f, &b) in step_bounds.iter().enumerate() {
        if b != EXACT {
            q = q
                .with(FIELD_NAMES[f], ErrorBound::Abs(RESIDUAL_MARGIN * b))
                .expect("FIELD_NAMES entries are valid fields");
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyframe_cadence() {
        let k = TemporalConfig::new(4).unwrap();
        let flags: Vec<bool> = (0..9).map(|t| k.is_keyframe(t)).collect();
        assert_eq!(
            flags,
            [true, false, false, false, true, false, false, false, true]
        );
        assert!(TemporalConfig::new(1).unwrap().is_keyframe(7), "K=1: all keyframes");
        assert!(TemporalConfig::new(0).is_err());
        assert!(TemporalConfig::new(usize::MAX).is_err());
    }

    fn st(min: f32, max: f32) -> FieldStats {
        FieldStats {
            min,
            max,
            min_abs: min.abs().min(max.abs()) as f64,
            entropy_bits: 0.0,
        }
    }

    #[test]
    fn delta_bounds_keep_comfortable_bounds_and_degrade_tight_ones() {
        let stats: [FieldStats; 6] = std::array::from_fn(|_| st(0.0, 256.0));
        // A typical rel:1e-4 resolution: far above the f32 ulp at 256.
        let resolved = [256.0 * 1e-4; 6];
        assert_eq!(delta_bounds(&resolved, &stats), resolved);
        // A bound at the rounding guard degrades to passthrough...
        let tight = [256.0 * 1e-9; 6];
        assert_eq!(delta_bounds(&tight, &stats), [EXACT; 6]);
        // ...and an exact bound stays exact.
        assert_eq!(delta_bounds(&[EXACT; 6], &stats), [EXACT; 6]);
    }

    #[test]
    fn residual_quality_maps_fields() {
        let b = [1e-3, EXACT, 2e-3, EXACT, EXACT, 4e-3];
        let q = residual_quality(&b);
        assert_eq!(q.bound(0), ErrorBound::Abs(RESIDUAL_MARGIN * 1e-3));
        assert_eq!(q.bound(1), ErrorBound::Lossless);
        assert_eq!(q.bound(2), ErrorBound::Abs(RESIDUAL_MARGIN * 2e-3));
        assert_eq!(q.bound(5), ErrorBound::Abs(RESIDUAL_MARGIN * 4e-3));
    }
}
