//! # nblc — Single-Snapshot Lossy Compression for N-Body Simulations
//!
//! `nblc` is a production-oriented framework reproducing Tao, Di, Chen &
//! Cappello, *"In-Depth Exploration of Single-Snapshot Lossy Compression
//! Techniques for N-Body Simulations"* (2017). It provides:
//!
//! * **Error-bounded lossy compressors** for 1D particle fields:
//!   SZ (LCF and LV prediction), CPC2000, FPZIP-like, ZFP-like,
//!   ISABELA-like, and a from-scratch DEFLATE-style lossless baseline.
//! * **The paper's optimizations**: SZ-LV, segmented R-index sorting
//!   (SZ-LV-RX), partial-radix R-index sorting (SZ-LV-PRX), and the
//!   combined SZ-CPC2000, exposed as three compression *modes*
//!   (`best_speed`, `best_tradeoff`, `best_compression`).
//! * **An in-situ streaming coordinator**: sharding, bounded-queue
//!   backpressure, worker scheduling, and a GPFS-like parallel-file-system
//!   model for scaling studies.
//! * **A SIMD kernel backend** ([`kernels`]) for the quantize / entropy /
//!   key-build hot loops, selected once at startup by runtime feature
//!   detection, with compressed bytes bit-identical to the scalar
//!   reference on every backend (`NBLC_SIMD=off|auto|force`, `--simd`).
//! * **Benchmark harnesses** regenerating every table and figure of the
//!   paper's evaluation section (see `benches/`).
//!
//! ## Quickstart: codec specs + quality targets
//!
//! Compressors are built from a **codec spec** — `name:key=val,key=val`
//! — through the central registry in [`compressors::registry`]. Bare
//! names (`sz_lv`), tuned parameters (`sz_lv_rx:segment=4096`, swept in
//! the paper's Table IV), and the paper's mode selector
//! (`mode:best_tradeoff`) all go through the same path. Compression
//! takes a typed [`quality::Quality`] target — one default
//! [`quality::ErrorBound`] (`abs:`/`rel:`/`pw_rel:`/`lossless`) plus
//! optional per-field overrides, e.g. tighter positions than
//! velocities:
//!
//! ```no_run
//! use nblc::compressors::registry;
//! use nblc::data::gen_md::{MdConfig, generate_md};
//! use nblc::quality::{ErrorBound, Quality};
//!
//! let snap = generate_md(&MdConfig { n_particles: 100_000, ..Default::default() });
//! let comp = registry::build_str("sz_lv_rx:segment=4096").unwrap();
//! // rel: value-range-relative (the paper's §III bound); coords get an
//! // absolute 1e-3 override.
//! let quality = Quality::rel(1e-4).with_coords(ErrorBound::Abs(1e-3));
//! let bundle = comp.compress(&snap, &quality).unwrap();
//! println!("ratio = {:.2}", bundle.compression_ratio());
//! let restored = comp.decompress(&bundle).unwrap();
//! assert_eq!(restored.len(), snap.len());
//! ```
//!
//! The bare-`f64` entry points of earlier releases (`compress_rel`,
//! `compress_with_rel`, the bare-float bound spelling) were removed in
//! 0.7; spell the same bound `Quality::rel(eb_rel)` / `rel:<v>`.
//!
//! ## Planning before compressing
//!
//! [`quality::SnapshotStats::collect`] takes a cheap contiguous-block
//! sample (~1% of the data), and
//! [`snapshot::SnapshotCompressor::plan`] resolves a quality against it
//! while estimating ratio and throughput — so a driver (or `nblc
//! compress --quality auto:target_ratio=6`, via
//! [`compressors::registry::plan_auto`]) can pick the right codec before
//! touching the full snapshot:
//!
//! ```no_run
//! # use nblc::compressors::registry;
//! # use nblc::data::gen_md::{MdConfig, generate_md};
//! use nblc::quality::{Quality, SnapshotStats};
//!
//! # let snap = generate_md(&MdConfig { n_particles: 100_000, ..Default::default() });
//! let stats = SnapshotStats::collect(&snap);
//! let quality = Quality::rel(1e-4);
//! let plan = registry::build_str("sz_lv").unwrap().plan(&stats, &quality).unwrap();
//! println!("est ratio {:.2} at {:.0} MB/s", plan.est_ratio, plan.est_compress_mbps);
//! let (codec, _plan) = registry::plan_auto(&stats, &quality, Some(6.0)).unwrap();
//! println!("auto picked {codec}");
//! ```
//!
//! ## Self-describing archives
//!
//! [`data::archive`] persists a compressed snapshot together with the
//! *canonical* spec that produced it (defaults filled in), magic +
//! format version, and per-field CRC32s, so decompression needs nothing
//! but the file — even for non-default parameters:
//!
//! ```no_run
//! # use nblc::compressors::registry;
//! # use nblc::data::gen_md::{MdConfig, generate_md};
//! use nblc::data::archive;
//! use std::path::Path;
//!
//! # let snap = generate_md(&MdConfig { n_particles: 1000, ..Default::default() });
//! use nblc::quality::Quality;
//! let spec = registry::canonical("sz_lv_rx:segment=4096").unwrap();
//! let bundle = registry::build_str(&spec).unwrap()
//!     .compress(&snap, &Quality::rel(1e-4)).unwrap();
//! archive::write(Path::new("out.nblc"), &bundle, &spec).unwrap();
//!
//! let arch = archive::read(Path::new("out.nblc")).unwrap();
//! let restored = registry::build_str(&arch.spec).unwrap()
//!     .decompress(&arch.bundle).unwrap();
//! ```
//!
//! Pipelines build one compressor per worker thread from the same spec
//! via [`compressors::registry::factory`]. `nblc list-codecs` prints
//! every registered codec with its tunable-parameter schema.
//!
//! ## Sharded, seekable archives (v3)
//!
//! The in-situ pipeline writes **v3** archives: every shard (particle
//! range + per-field CRCs + payload) is an independent record, streamed
//! in completion order; a seekable footer holds the shard table
//! (offsets, lengths, per-shard cost counters) in logical order. That
//! buys parallel decompression (shard decodes fan out across an
//! [`exec::ExecCtx`]) and partial reads that only touch overlapping
//! shards. [`data::archive::ShardReader`] opens all three format
//! versions — v1/v2 single-record files present as one shard:
//!
//! ```no_run
//! use nblc::compressors::registry;
//! use nblc::data::archive::{decode_shards, ShardReader, ShardWriter};
//! use nblc::exec::ExecCtx;
//! # use nblc::data::gen_md::{MdConfig, generate_md};
//! use std::path::Path;
//!
//! # let snap = generate_md(&MdConfig { n_particles: 10_000, ..Default::default() });
//! use nblc::quality::Quality;
//! let quality = Quality::rel(1e-4);
//! let spec = registry::canonical("sz_lv").unwrap();
//! let comp = registry::build_str(&spec).unwrap();
//! let mut w = ShardWriter::create_quality(Path::new("out.nblc"), &spec, &quality).unwrap();
//! for (start, end) in [(0usize, 5_000), (5_000, 10_000)] {
//!     let bundle = comp.compress(&snap.slice(start, end), &quality).unwrap();
//!     w.write_shard(start, end, &bundle, 0).unwrap();
//! }
//! let index = w.finish().unwrap(); // validates coverage, writes footer
//! assert_eq!(index.entries.len(), 2);
//!
//! let reader = ShardReader::open(Path::new("out.nblc")).unwrap();
//! // Partial read: decodes only the shards overlapping [2000, 7000).
//! let part = decode_shards(&reader, reader.spec(), Some((2_000, 7_000)), &ExecCtx::auto()).unwrap();
//! assert_eq!(part.shards_touched, 2);
//! ```
//!
//! Determinism carries over: the archive's *file* bytes depend on shard
//! completion order, but the footer's logical order, each shard's
//! payload, and the decoded snapshot are bit-identical at any worker /
//! thread count.
//!
//! ## Durability & recovery
//!
//! v3 writes are crash-consistent by construction: `nblc compress`
//! stages through a temp file and commits with fsync + atomic rename,
//! while the streaming pipeline sink writes the footer *last* behind an
//! fsync barrier, so every byte the footer indexes is already on stable
//! storage. A writer killed mid-run therefore leaves a footer-less file
//! whose record prefix is still intact.
//! [`data::archive::ShardReader::open_salvage`] walks such a file
//! record by record, keeps the CRC-verified contiguous prefix, and
//! reconstructs an index for it; `export_salvaged` re-emits the prefix
//! as an intact archive (the `nblc salvage` command). Intact archives
//! pass through unchanged:
//!
//! ```no_run
//! use nblc::data::archive::ShardReader;
//! use std::path::Path;
//!
//! let (reader, report) = ShardReader::open_salvage(Path::new("torn.nblc")).unwrap();
//! println!(
//!     "recovered {} shards / {} particles ({} bytes lost past the tear)",
//!     report.shards_recovered,
//!     report.particles_recovered,
//!     report.bytes_lost,
//! );
//! // The salvaged prefix reads like any archive...
//! let bundle = reader.read_shard(0).unwrap();
//! # let _ = bundle;
//! // ...and can be materialized as an intact file, footer and all.
//! reader.export_salvaged(Path::new("recovered.nblc")).unwrap();
//! ```
//!
//! Upstream of the archive, `[pipeline] max_retries = N` gives each
//! shard task a bounded in-place retry (failed or panicked compressors
//! are rebuilt and re-run on the same worker, so a recovered run is
//! byte-identical to a fault-free one); what still fails degrades the
//! run into a typed [`Error::PartialFailure`] report instead of a
//! panic. The serve daemon drains gracefully on SIGTERM and falls back
//! to the salvage path when asked to serve a footer-less archive. The
//! deterministic fault-injection harness behind all of this lives in
//! [`testkit::failpoint`] (`NBLC_FAILPOINT=write:<N>[:enospc|eio|short]`).
//!
//! ## Spatial queries
//!
//! Archives written with the pipeline's `layout = "spatial"` carry a
//! footer spatial index — per shard, a Morton key range and an f32 AABB
//! of the *decoded* coordinates (plus optional per-segment boxes).
//! [`data::archive::decode_region`] intersects an axis-aligned query
//! box against that index, decodes only the overlapping shards, and
//! trims each to exact membership; because the boxes describe the
//! round-tripped values every future decode reproduces, pruning never
//! drops a member for any codec. Pre-spatial archives answer the same
//! query through a decode-everything fallback:
//!
//! ```no_run
//! use nblc::data::archive::{decode_region, Region, ShardReader};
//! use nblc::exec::ExecCtx;
//! use std::path::Path;
//!
//! let reader = ShardReader::open(Path::new("spatial.nblc")).unwrap();
//! // Half-open box, snapshot coordinate units.
//! let region = Region::new([10.0, 10.0, 10.0], [14.0, 14.0, 14.0]).unwrap();
//! let dec = decode_region(&reader, reader.spec(), &region, &ExecCtx::auto()).unwrap();
//! println!(
//!     "{} particles ({} shards decoded, {} pruned, indexed: {})",
//!     dec.snapshot.len(),
//!     dec.shards_touched,
//!     dec.shards_pruned,
//!     dec.indexed,
//! );
//! ```
//!
//! ## Temporal streams
//!
//! [`temporal`] extends the v3 archive to multi-snapshot time series:
//! [`coordinator::pipeline::run_insitu_stream`] writes a keyframe+delta
//! chain (every K-th timestep stored whole, the rest as SZ-quantized
//! residuals against a velocity-extrapolated prediction from the
//! previous *decoded* step — so quantization error never accumulates,
//! and every timestep reconstructs within the typed [`quality::Quality`]
//! bound). [`data::archive::ShardReader::decode_timestep`] seeks to any
//! step touching only its keyframe group — O(K) records, independent of
//! stream length:
//!
//! ```no_run
//! use nblc::compressors::registry;
//! use nblc::coordinator::pipeline::{run_insitu_stream, StreamConfig};
//! use nblc::data::archive::ShardReader;
//! use nblc::data::gen_cosmo::{self, CosmoConfig};
//! use nblc::exec::ExecCtx;
//! use nblc::quality::Quality;
//! use nblc::temporal::TemporalConfig;
//! use std::path::PathBuf;
//!
//! // 16 leapfrog timesteps of a cosmology snapshot.
//! let cfg = CosmoConfig { n_particles: 100_000, ..Default::default() };
//! let series = gen_cosmo::time_series(&cfg, 16, 0.05);
//! let path = PathBuf::from("stream.nblc");
//! let report = run_insitu_stream(&series, &StreamConfig {
//!     shards: 8,
//!     threads: 0,
//!     quality: Quality::rel(1e-4),
//!     // Stream mode needs an order-preserving codec (residuals are
//!     // particle-index-aligned); the RX family is rejected typed.
//!     factory: registry::factory("sz_lv").unwrap(),
//!     path: path.clone(),
//!     spec: registry::canonical("sz_lv").unwrap(),
//!     temporal: TemporalConfig::new(4).unwrap(), // keyframe every 4
//!     dt: 0.05,
//!     max_retries: 0,
//! }).unwrap();
//! println!("delta steps {:.1}x smaller than keyframes",
//!     report.delta_vs_keyframe().unwrap_or(1.0));
//!
//! // Mid-chain seek: replays 4..=6 only, never steps 0..4 or 7..
//! let reader = ShardReader::open(&path).unwrap();
//! let dec = reader.decode_timestep(6, &ExecCtx::auto()).unwrap();
//! assert_eq!(dec.keyframe, 4);
//! assert_eq!(dec.shards_touched, reader.shards_for_timestep(6).unwrap().len());
//! ```
//!
//! The CLI face is `nblc pipeline --stream` / `nblc decompress
//! --timestep t` / `nblc get --timestep t` (served seeks share the LRU
//! shard cache); `nblc inspect` prints the chain table.
//!
//! ## Threading model
//!
//! Every snapshot compressor is driven by an [`exec::ExecCtx`] — a
//! thread budget plus reusable scratch buffers. The six field planes
//! (and the segmented R-index sort's segments) are independent work
//! items, so `compress_with`/`decompress_with` fan them across the
//! budget; the plain `compress`/`decompress` wrappers stay sequential.
//!
//! ```no_run
//! # use nblc::compressors::registry;
//! # use nblc::data::gen_md::{MdConfig, generate_md};
//! use nblc::exec::ExecCtx;
//!
//! # let snap = generate_md(&MdConfig { n_particles: 100_000, ..Default::default() });
//! use nblc::quality::Quality;
//! let quality = Quality::rel(1e-4);
//! let comp = registry::build_str("sz_lv_rx").unwrap();
//! let ctx = ExecCtx::auto(); // NBLC_THREADS env, else all cores
//! let bundle = comp.compress_with(&ctx, &snap, &quality).unwrap();
//! // Hard guarantee: identical bytes at ANY thread count.
//! let sequential = comp.compress(&snap, &quality).unwrap();
//! for (par, seq) in bundle.fields.iter().zip(sequential.fields.iter()) {
//!     assert_eq!(par.bytes, seq.bytes);
//! }
//! ```
//!
//! **Determinism guarantee**: compressed bytes are identical for every
//! thread count (enforced by `tests/parallel_determinism.rs`), because
//! parallelism only reschedules independent work items — archives never
//! depend on the machine that wrote them. The CLI exposes the budget as
//! `--threads N` (default: `NBLC_THREADS`, else all cores); the in-situ
//! pipeline multiplies it per worker (`threads` in `[pipeline]`
//! config). Parallelism pays off from roughly 10⁵ particles upward;
//! below that, thread spawn overhead dominates and `ExecCtx::sequential`
//! (or the plain wrappers) is the right call.
//!
//! The same determinism contract covers the [`kernels`] backend table
//! the context carries: scalar and SIMD kernels produce bit-identical
//! archives, so backend selection — like the thread budget — is a pure
//! scheduling choice (enforced by `tests/backend_equivalence.rs`).
//!
//! ## Serving archives
//!
//! `nblc serve a.nblc b.nblc` turns the read path into a long-running
//! daemon ([`serve`]): archives stay open, decoded shards sit in a
//! weight-bounded LRU cache, and admission control sheds overload with
//! a typed `Busy` instead of queueing unboundedly. [`serve::ServeClient`]
//! is the library-side counterpart of `nblc get`:
//!
//! ```no_run
//! use nblc::serve::{GetReply, ServeClient};
//!
//! # fn main() -> nblc::Result<()> {
//! let mut client = ServeClient::connect("127.0.0.1:7117")?;
//! match client.get("snap.nblc", Some((10_000, 20_000)))? {
//!     GetReply::Data(d) => {
//!         // Exact for order-preserving codecs; whole overlapping
//!         // shards (d.exact == false) for the RX reordering family.
//!         println!("{} particles, {} cache hits", d.snapshot.len(), d.cache_hits);
//!     }
//!     GetReply::Busy(b) => println!("shed: {}/{} in flight", b.inflight, b.max_inflight),
//! }
//! let stats = client.stats()?;
//! println!("cache hit rate: {}/{}", stats.cache_hits, stats.cache_hits + stats.cache_misses);
//! # Ok(())
//! # }
//! ```

pub mod error;
pub mod util;
pub mod kernels;
pub mod exec;
pub mod testkit;
pub mod codec;
pub mod model;
pub mod rindex;
pub mod quality;
pub mod data;
pub mod snapshot;
pub mod compressors;
pub mod temporal;
pub mod metrics;
pub mod config;
pub mod cli;
pub mod coordinator;
pub mod serve;
pub mod bench;

pub use error::{Error, Result};
