//! `nblc` — the launcher / leader entrypoint.
//!
//! Subcommands:
//!   gen         generate a synthetic snapshot to a file
//!   compress    compress a snapshot file with a codec spec
//!   decompress  decompress an archive back to a snapshot file
//!   inspect     print an archive's self-description (spec, fields, CRCs)
//!   salvage     recover the verified prefix of a torn / footer-less archive
//!   list-codecs show every registered codec and its tunable parameters
//!   analyze     distortion report (max err / NRMSE / PSNR per field)
//!   pipeline    run the in-situ pipeline from a config file
//!   serve       long-running archive service daemon (LRU shard cache)
//!   get         query a running serve daemon for a particle range
//!   info        print dataset / kernel-backend diagnostics

use nblc::cli::Args;
use nblc::compressors::registry;
use nblc::config::{ConfigDoc, PipelineSettings, ServeSettings, TemporalSettings};
use nblc::coordinator::pipeline::{
    run_insitu, run_insitu_stream, InsituConfig, InsituReport, Sink, SpatialInsitu, StreamConfig,
};
use nblc::coordinator::shard::{rebalance, Shard};
use nblc::coordinator::spatial::{plan_spatial, rebalance_aligned};
use nblc::coordinator::{choose_compressor, GpfsModel};
use nblc::data::archive::{decode_region, decode_shards, Region, ShardReader, ShardWriter};
use nblc::data::io::{read_snapshot, write_snapshot};
use nblc::data::{generate, generate_series, DatasetKind};
use nblc::error::{Error, Result};
use nblc::exec::ExecCtx;
use nblc::metrics::ErrorStats;
use nblc::quality::{ErrorBound, Plan, Quality, SnapshotStats, EXACT};
use nblc::serve::{GetReply, ServeClient, ServeConfig, Server};
use nblc::snapshot::FIELD_NAMES;
use nblc::util::humansize;
use nblc::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};

const HELP: &str = "\
nblc — single-snapshot lossy compression for N-body simulations

USAGE: nblc <command> [flags]

COMMANDS:
  gen         --dataset hacc|amdf --n <count> --seed <u64> --out <file>
  compress    <in.snap> <out.nblc> --method <spec> [--eb <bound>]
              [--quality <quality>|auto[:target_ratio=<x>]] [--threads N]
              [--simd off|auto|force]
  decompress  <in.nblc> <out.snap> [--method <spec>] [--threads N]
              [--particles a..b] [--region x0..x1,y0..y1,z0..z1]
              [--timestep T] [--simd off|auto|force]
  inspect     <in.nblc> [--verify]
  salvage     <in.nblc> [--output <out.nblc>]
  list-codecs
  analyze     <orig.snap> <recon.snap>
  pipeline    --config <file.toml> [--threads N] [--simd off|auto|force]
              [--stream] [--keyframe-every K] [--steps T] [--dt X]
  serve       <archive.nblc>... [--config <file.toml>] [--addr host:port]
              [--cache_mb N] [--max_inflight N] [--queue_timeout_ms N]
              [--decode_budget_ms N] [--threads N] [--simd off|auto|force]
  get         [<archive>] [--addr host:port] [--particles a..b]
              [--region x0..x1,y0..y1,z0..z1] [--timestep T]
              [--out <file.snap>] [--stats] [--retries N]
  info        [--simd off|auto|force]

A codec spec is `name:key=val,key=val`, e.g. `sz_lv`,
`sz_lv_rx:segment=4096`, `sz:pred=lv`, or `mode:best_tradeoff`.
Archives are self-describing: `decompress` needs no --method.
Run `nblc list-codecs` for every codec and tunable parameter.

Quality targets are typed. --eb takes one bound for every field:
`abs:1e-3` (absolute), `rel:1e-4` (value-range-relative, the paper's
definition), `pw_rel:1e-3`
(pointwise-relative), or `lossless`. --quality takes a full per-field
spec such as `rel:1e-4,coords=abs:1e-3`, or `auto[:target_ratio=<x>]`
to let the planner pick the codec from a cheap sampled pass. A spec's
`eb=` parameter (e.g. `sz_lv:eb=abs:1e-3`) is the default when neither
flag is given. compress writes a single-shard v3 archive whose footer
records the canonical quality and the resolved per-field bounds;
`inspect` prints them (pre-quality archives report n/a).

decompress reads v1/v2 single-record archives and sharded v3 archives
(written by `pipeline` with `output = \"...\"`). For v3, shard decodes
fan out across --threads, and --particles a..b decodes only the shards
overlapping that range (seekable partial read). inspect prints the v3
shard table; --verify additionally streams the whole-file CRC.

--region x0..x1,y0..y1,z0..z1 (decompress and get; half-open per
axis) extracts exactly the particles inside an axis-aligned box. On
an archive written with `layout = \"spatial\"` in [pipeline] (Morton-
aligned shards + a footer bbox index) only the shards overlapping the
box are decoded; pre-spatial archives still answer via a full scan.
inspect prints the spatial block when present.

--threads N sets the engine's thread budget. For compress/decompress
the default is the NBLC_THREADS env var, else all available cores;
pipeline defaults to 1 per worker (workers already run in parallel)
unless the config or --threads says otherwise, with 0 = auto.
Compressed bytes are identical at every thread count.

--simd off|auto|force picks the kernel backend for the quantize /
entropy / key-build hot loops (default: the NBLC_SIMD env var, else
auto = runtime feature detection). Compressed bytes are bit-identical
on every backend; `nblc info` prints what auto selects.

serve holds v3 archives open behind a TCP daemon with an LRU cache of
decoded shards and admission control: over-budget load is shed with a
typed Busy response instead of queueing unboundedly. Defaults come
from the config's [serve] section (addr, cache_mb, max_inflight,
queue_timeout_ms, decode_budget_ms, threads); flags override. get
addresses archives by basename (omit it when one archive is served),
reuses --particles a..b for ranges, --retries N waits out Busy sheds
with jittered backoff, and --stats prints the daemon's cache/admission
counters. SIGTERM/SIGINT drain the daemon gracefully: in-flight
requests complete before the process exits.

pipeline --stream compresses a whole time series into one temporal
archive: every K-th timestep (--keyframe-every, or [temporal]
keyframe_interval) is stored as a keyframe, the rest as SZ-quantized
residuals against a velocity extrapolation (x + v*dt) of the previous
*decoded* timestep — prediction always runs off decoded data, so
error never accumulates past the quality bound at any chain depth.
--steps / --dt (or [temporal] steps / dt) size the generated series.
Reordering codecs are rejected (residuals are index-aligned).
decompress --timestep T and get --timestep T reconstruct one timestep
by decoding only its keyframe group (keyframe through T, at most K
steps), never the whole stream; inspect prints the chain table.

Durability: pipeline archives are written footer-last with fsync
barriers, and `nblc compress` writes through a temp file + atomic
rename. A run killed mid-write leaves a footer-less file; `salvage`
walks its records, keeps the CRC-verified contiguous prefix, and
re-exports it as an intact archive. `[pipeline] max_retries = N`
retries failed/panicked shard tasks in place before a run degrades to
a typed partial-failure report.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print!("{HELP}");
        return;
    }
    // Boolean switches declared up front so they never swallow a
    // following positional (e.g. `inspect --verify file.nblc`).
    let parsed = match Args::parse_with_switches(args, &["verify", "stats", "stream"]) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&parsed) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "gen" => cmd_gen(args),
        "compress" => cmd_compress(args),
        "decompress" => cmd_decompress(args),
        "inspect" => cmd_inspect(args),
        "salvage" => cmd_salvage(args),
        "list-codecs" => cmd_list_codecs(args),
        "analyze" => cmd_analyze(args),
        "pipeline" => cmd_pipeline(args),
        "serve" => cmd_serve(args),
        "get" => cmd_get(args),
        "info" => cmd_info(args),
        other => Err(Error::invalid(format!(
            "unknown command '{other}' (try --help)"
        ))),
    }
}

fn dataset_kind(name: &str) -> Result<DatasetKind> {
    match name {
        "hacc" => Ok(DatasetKind::Hacc),
        "amdf" => Ok(DatasetKind::Amdf),
        _ => Err(Error::invalid(format!("unknown dataset '{name}'"))),
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    args.expect_known(&["dataset", "n", "seed", "out"])?;
    let kind = dataset_kind(&args.get_or("dataset", "hacc"))?;
    let n: usize = args.get_parse("n", 1_000_000)?;
    let seed: u64 = args.get_parse("seed", nblc::bench::BENCH_SEED)?;
    let out = PathBuf::from(args.get_or("out", "snapshot.snap"));
    let t = Timer::start();
    let snap = generate(kind, n, seed);
    write_snapshot(&snap, &out)?;
    println!(
        "generated {} ({} particles, {}) in {} -> {}",
        kind.name(),
        snap.len(),
        humansize::bytes(snap.total_bytes() as u64),
        humansize::secs(t.secs()),
        out.display()
    );
    Ok(())
}

/// Resolve the `--threads` flag: explicit value > `NBLC_THREADS` env >
/// available parallelism (`--threads 0` also means auto). Also applies
/// the `--simd` backend choice so the context (and every ctx-less call
/// site behind [`nblc::kernels::active`]) agrees on one table.
fn exec_ctx(args: &Args) -> Result<ExecCtx> {
    let threads: usize = args.get_parse("threads", 0)?;
    let kern = simd_kernels(args)?;
    Ok(ExecCtx::resolve(threads).with_kernels(kern))
}

/// Resolve `--simd off|auto|force` (default: the `NBLC_SIMD` env var,
/// else auto): an explicit flag sets the process-wide mode, then the
/// active table is returned.
fn simd_kernels(args: &Args) -> Result<&'static nblc::kernels::Kernels> {
    if let Some(s) = args.get("simd") {
        let mode = nblc::kernels::SimdMode::parse(s)
            .ok_or_else(|| Error::invalid(format!("--simd expects off|auto|force, got '{s}'")))?;
        nblc::kernels::set_mode(mode);
    }
    Ok(nblc::kernels::active())
}

/// Parse a `--quality auto[:target_ratio=<x>]` value. `Some(target)`
/// when the flag requests auto planning, `None` otherwise.
fn parse_auto(q: &str) -> Result<Option<Option<f64>>> {
    if q == "auto" {
        return Ok(Some(None));
    }
    if let Some(rest) = q.strip_prefix("auto:") {
        let tr = rest.strip_prefix("target_ratio=").ok_or_else(|| {
            Error::invalid(format!(
                "--quality auto takes 'auto' or 'auto:target_ratio=<x>', got '{q}'"
            ))
        })?;
        let t: f64 = tr
            .parse()
            .map_err(|_| Error::invalid(format!("target_ratio '{tr}' is not a number")))?;
        if !(t >= 1.0) || !t.is_finite() {
            return Err(Error::invalid(format!("target_ratio must be >= 1, got {t}")));
        }
        return Ok(Some(Some(t)));
    }
    Ok(None)
}

/// Resolve the compress-side quality from the flags and the spec's
/// `eb=` hint: `--quality` > `--eb` > spec hint > `rel:1e-4`.
fn resolve_quality(args: &Args, method: &str) -> Result<Quality> {
    let eb_flag = match args.get("eb") {
        Some(s) => Some(ErrorBound::parse(s)?),
        None => None,
    };
    if let Some(q) = args.get("quality") {
        if parse_auto(q)?.is_none() {
            if eb_flag.is_some() {
                return Err(Error::invalid(
                    "give --quality or --eb, not both (a quality spec already \
                     carries its default bound)",
                ));
            }
            return Quality::parse(q);
        }
    }
    if let Some(b) = eb_flag {
        return Ok(Quality::new(b));
    }
    if let Some(hint) = registry::quality_hint(method)? {
        return Ok(Quality::new(hint));
    }
    Ok(Quality::default())
}

fn print_plan(plan: &Plan) {
    println!(
        "plan: codec {} (quality {}), est ratio {:.2} ({:.2} bits/value), est {} \
         [sampled {} of {} particles]",
        plan.codec,
        plan.quality,
        plan.est_ratio,
        plan.est_bits_per_value,
        humansize::rate(plan.est_compress_mbps * 1e6),
        plan.sample_particles,
        plan.total_particles,
    );
    println!("{:>8} {:>16} {:>14} {:>10}", "field", "bound", "eb_abs", "est b/v");
    for f in &plan.fields {
        println!(
            "{:>8} {:>16} {:>14} {:>10.2}",
            f.name,
            f.bound.canonical(),
            fmt_bound(f.eb_abs),
            f.est_bits_per_value,
        );
    }
}

/// Render a resolved absolute bound (the [`EXACT`] sentinel reads as
/// "exact").
fn fmt_bound(eb: f64) -> String {
    if eb == EXACT {
        "exact".into()
    } else {
        format!("{eb:.3e}")
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    args.expect_known(&["method", "eb", "quality", "threads", "simd"])?;
    let [input, output] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: compress <in.snap> <out.nblc>"));
    };
    let method = args.get_or("method", "sz_lv");
    let ctx = exec_ctx(args)?;
    let snap = read_snapshot(Path::new(input))?;
    let quality = resolve_quality(args, &method)?;
    // --quality auto[:target_ratio=x]: plan every candidate codec on a
    // cheap block sample and pick before touching the full data.
    let auto = match args.get("quality") {
        Some(q) => parse_auto(q)?,
        None => None,
    };
    let spec = if let Some(target) = auto {
        let stats = SnapshotStats::collect(&snap);
        let (name, plan) = registry::plan_auto(&stats, &quality, target)?;
        print_plan(&plan);
        if args.get("method").is_some() {
            println!("(--quality auto overrides --method {method})");
        }
        registry::canonical(&name)?
    } else {
        registry::canonical(&method)?
    };
    // try_build_str so a bad --method prints the registry's typed
    // diagnostics (unknown parameter, value out of domain, ...).
    let comp = registry::try_build_str(&spec)?;
    let t = Timer::start();
    let bundle = comp.compress_with(&ctx, &snap, &quality)?;
    let secs = t.secs();
    let mut w = ShardWriter::create_quality(Path::new(output), &spec, &quality)?;
    w.write_shard(0, snap.len(), &bundle, (secs * 1e9) as u64)?;
    let index = w.finish()?;
    println!(
        "{spec}: {} -> {} (ratio {:.2}, {} at {}, {} threads)",
        humansize::bytes(bundle.original_bytes() as u64),
        humansize::bytes(bundle.compressed_bytes() as u64),
        bundle.compression_ratio(),
        humansize::secs(secs),
        humansize::rate(bundle.original_bytes() as f64 / secs),
        ctx.threads(),
    );
    if let Some(q) = &index.quality {
        println!("quality:   {} (resolved per-field bounds below)", q.quality);
        println!("{:>8} {:>14}", "field", "eb_abs");
        for (f, name) in FIELD_NAMES.iter().enumerate() {
            println!("{:>8} {:>14}", name, fmt_bound(q.field_bounds[f]));
        }
    }
    println!("archived spec: {spec} (v3, 1 shard)");
    Ok(())
}

/// Parse a `--particles a..b` range.
fn parse_particles(s: &str) -> Result<(u64, u64)> {
    let err = || Error::invalid(format!("--particles expects 'start..end', got '{s}'"));
    let (a, b) = s.split_once("..").ok_or_else(err)?;
    let a: u64 = a.trim().parse().map_err(|_| err())?;
    let b: u64 = b.trim().parse().map_err(|_| err())?;
    if a >= b {
        return Err(Error::invalid(format!("--particles range '{s}' is empty")));
    }
    Ok((a, b))
}

/// Parse a `--region x0..x1,y0..y1,z0..z1` box (half-open per axis).
fn parse_region(s: &str) -> Result<Region> {
    let err = || {
        Error::invalid(format!(
            "--region expects 'x0..x1,y0..y1,z0..z1', got '{s}'"
        ))
    };
    let mut min = [0f32; 3];
    let mut max = [0f32; 3];
    let axes: Vec<&str> = s.split(',').collect();
    if axes.len() != 3 {
        return Err(err());
    }
    for (a, axis) in axes.iter().enumerate() {
        let (lo, hi) = axis.split_once("..").ok_or_else(err)?;
        min[a] = lo.trim().parse().map_err(|_| err())?;
        max[a] = hi.trim().parse().map_err(|_| err())?;
    }
    Region::new(min, max)
}

fn cmd_decompress(args: &Args) -> Result<()> {
    args.expect_known(&["method", "threads", "particles", "region", "timestep", "simd"])?;
    let [input, output] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: decompress <in.nblc> <out.snap>"));
    };
    let reader = ShardReader::open(Path::new(input))?;
    // The archive is self-describing; --method only overrides it.
    let spec = args
        .get("method")
        .map(str::to_string)
        .unwrap_or_else(|| reader.spec().to_string());
    if args.get("region").is_some() && args.get("particles").is_some() {
        return Err(Error::invalid(
            "give --region or --particles, not both (a box query selects \
             by position, not by index)",
        ));
    }
    if let Some(ts) = args.get("timestep") {
        if args.get("particles").is_some() || args.get("region").is_some() {
            return Err(Error::invalid(
                "give --timestep alone: it selects a whole chain step, not a \
                 particle range or box",
            ));
        }
        let t: usize = ts
            .parse()
            .map_err(|_| Error::invalid(format!("--timestep: cannot parse '{ts}'")))?;
        let ctx = exec_ctx(args)?;
        let timer = Timer::start();
        let dec = reader.decode_timestep(t, &ctx)?;
        write_snapshot(&dec.snapshot, Path::new(output))?;
        println!(
            "timestep {t}: {} particles [{}..{}] in {} ({} of {} shards decoded; \
             chain replayed from keyframe {}, {} threads)",
            dec.snapshot.len(),
            dec.particle_start,
            dec.particle_end,
            humansize::secs(timer.secs()),
            dec.shards_touched,
            reader.index().entries.len(),
            dec.keyframe,
            ctx.threads(),
        );
        return Ok(());
    }
    if let Some(rs) = args.get("region") {
        let region = parse_region(rs)?;
        let ctx = exec_ctx(args)?;
        let t = Timer::start();
        let dec = decode_region(&reader, &spec, &region, &ctx)?;
        write_snapshot(&dec.snapshot, Path::new(output))?;
        println!(
            "region [{rs}]: {} particles via '{spec}' in {} ({} shards decoded, {} pruned {}, {} threads)",
            dec.snapshot.len(),
            humansize::secs(t.secs()),
            dec.shards_touched,
            dec.shards_pruned,
            if dec.indexed {
                "by the spatial index"
            } else {
                "(no spatial index: full scan)"
            },
            ctx.threads(),
        );
        return Ok(());
    }
    let range = match args.get("particles") {
        Some(s) => Some(parse_particles(s)?),
        None => None,
    };
    let ctx = exec_ctx(args)?;
    let t = Timer::start();
    let dec = decode_shards(&reader, &spec, range, &ctx)?;
    write_snapshot(&dec.snapshot, Path::new(output))?;
    println!(
        "decompressed {} particles [{}..{}] via '{spec}' in {} ({}/{} shards, {}, {} threads)",
        dec.snapshot.len(),
        dec.particle_start,
        dec.particle_end,
        humansize::secs(t.secs()),
        dec.shards_touched,
        reader.index().entries.len(),
        if dec.reordered {
            "R-index particle order per shard"
        } else {
            "original particle order"
        },
        ctx.threads(),
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.expect_known(&["verify"])?;
    let [input] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: inspect <in.nblc> [--verify]"));
    };
    let verify = args.has("verify");
    // A torn v3 archive (crashed writer, truncated copy) still has a
    // readable prefix: name the last structurally-valid shard and point
    // at `nblc salvage` instead of a bare corruption error.
    let reader = match ShardReader::open(Path::new(input)) {
        Ok(reader) => reader,
        Err(Error::Io(e)) => return Err(Error::Io(e)),
        Err(first) => match ShardReader::open_salvage(Path::new(input)) {
            Ok((_, rep)) if !rep.had_footer => {
                let tail = match rep.last_valid {
                    Some((s, e, off)) => format!(
                        "last structurally-valid shard covers particles {s}..{e} \
                         (record at byte offset {off})"
                    ),
                    None => "no structurally-valid shard record survives".into(),
                };
                return Err(Error::Corrupt(format!(
                    "{first}; {} of {} bytes are a verifiable prefix; {tail}; \
                     run `nblc salvage {input}` to recover it",
                    rep.data_end,
                    rep.data_end + rep.bytes_lost,
                )));
            }
            _ => return Err(first),
        },
    };
    let idx = reader.index();
    let orig_bytes = idx.original_bytes();
    let comp_bytes = idx.compressed_bytes();
    let ratio = if comp_bytes > 0 {
        orig_bytes as f64 / comp_bytes as f64
    } else {
        f64::INFINITY
    };
    println!("archive:   {input}");
    println!("format:    v{}", reader.version());
    println!("spec:      {}", idx.spec);
    println!("kernels:   {} (decode backend; bytes are backend-invariant)", nblc::kernels::active().label);
    match &idx.quality {
        Some(q) => {
            println!("quality:   {}", q.quality);
            println!("{:>8} {:>14}", "field", "eb_abs");
            for (f, name) in FIELD_NAMES.iter().enumerate() {
                println!("{:>8} {:>14}", name, fmt_bound(q.field_bounds[f]));
            }
        }
        None => {
            println!("quality:   n/a (pre-quality archive)");
            println!("eb_rel:    {:.3e}", idx.eb_rel);
        }
    }
    println!("particles: {}", idx.n);
    println!(
        "size:      {} -> {} (ratio {ratio:.2}, {:.2} bits/value)",
        humansize::bytes(orig_bytes),
        humansize::bytes(comp_bytes),
        32.0 / ratio,
    );
    if let Some(bundle) = reader.single_record() {
        // v1/v2: one record, per-field breakdown.
        println!(
            "integrity: {}",
            if reader.version() >= 2 {
                "per-field CRC32 verified"
            } else {
                "v1 bundle (no checksums)"
            }
        );
        println!("{:>8} {:>12} {:>12} {:>8}", "field", "values", "bytes", "ratio");
        for f in &bundle.fields {
            println!(
                "{:>8} {:>12} {:>12} {:>8.2}",
                f.name,
                f.n,
                f.bytes.len(),
                f.ratio()
            );
        }
    } else {
        // v3: seekable shard table from the footer.
        println!("integrity: footer CRC verified (per-field CRCs checked on read)");
        println!(
            "{:>6} {:>17} {:>12} {:>12} {:>8} {:>10}",
            "shard", "particles", "offset", "bytes", "ratio", "cost_ms"
        );
        for (i, e) in idx.entries.iter().enumerate() {
            let shard_ratio = if e.bytes_out > 0 {
                e.original_bytes() as f64 / e.bytes_out as f64
            } else {
                f64::INFINITY
            };
            println!(
                "{:>6} {:>8}..{:<8} {:>12} {:>12} {:>8.2} {:>10.2}",
                i,
                e.start,
                e.end,
                e.offset,
                e.bytes_out,
                shard_ratio,
                e.cost_nanos as f64 / 1e6,
            );
        }
    }
    if reader.single_record().is_none() {
        match reader.spatial() {
            Some(sp) => {
                println!(
                    "spatial:   Morton {} bits/axis, {} segment boxes per shard (seg={})",
                    sp.bits,
                    if sp.seg > 0 { "with" } else { "no" },
                    sp.seg,
                );
                println!(
                    "{:>6} {:>34} {:>44}",
                    "shard", "morton range", "bbox [x0..x1 y0..y1 z0..z1]"
                );
                for (i, s) in sp.shards.iter().enumerate() {
                    println!(
                        "{:>6} {:>16x}..{:<16x} [{:>9.3e}..{:<9.3e} {:>9.3e}..{:<9.3e} {:>9.3e}..{:<9.3e}]",
                        i,
                        s.mkey_lo,
                        s.mkey_hi,
                        s.bbox[0],
                        s.bbox[1],
                        s.bbox[2],
                        s.bbox[3],
                        s.bbox[4],
                        s.bbox[5],
                    );
                }
            }
            None => {
                println!("spatial:   n/a (no spatial index; --region falls back to a full scan)")
            }
        }
        if let Some(tc) = reader.temporal() {
            let keyframes = tc.steps.iter().filter(|s| s.keyframe).count();
            println!(
                "temporal:  {} timesteps ({keyframes} keyframes at interval {}), {} particles/step",
                tc.steps.len(),
                tc.interval,
                idx.n / tc.steps.len().max(1) as u64,
            );
            println!(
                "{:>6} {:>5} {:>13} {:>10}   {}",
                "step", "kind", "shards", "dt", "bounds [xx yy zz vx vy vz]"
            );
            for (t, s) in tc.steps.iter().enumerate() {
                let bounds: Vec<String> = s.bounds.iter().map(|&b| fmt_bound(b)).collect();
                println!(
                    "{:>6} {:>5} {:>5}..{:<6} {:>10.3e}   [{}]",
                    t,
                    if s.keyframe { "key" } else { "delta" },
                    s.shard_lo,
                    s.shard_hi,
                    s.dt,
                    bounds.join(" "),
                );
            }
        }
    }
    if verify {
        match reader.version() {
            3 => {
                reader.verify_file_crc()?;
                println!("whole-file CRC: OK (covers shard payloads and the full footer, spatial block included)");
            }
            2 => println!("whole-file CRC: n/a (v2: header + per-field CRCs verified at open)"),
            _ => println!("whole-file CRC: n/a (v1 bundles carry no checksums)"),
        }
    }
    Ok(())
}

fn cmd_salvage(args: &Args) -> Result<()> {
    args.expect_known(&["output"])?;
    let [input] = args.positionals.as_slice() else {
        return Err(Error::invalid(
            "usage: salvage <in.nblc> [--output <out.nblc>]",
        ));
    };
    let (reader, report) = ShardReader::open_salvage(Path::new(input))?;
    if report.had_footer {
        println!(
            "{input}: archive is intact ({} shards, footer verified); nothing to salvage",
            report.shards_recovered
        );
        return Ok(());
    }
    println!("{input}: no footer (crashed or truncated write)");
    println!(
        "recovered: {} shards / {} particles (CRC-verified contiguous prefix)",
        report.shards_recovered, report.particles_recovered
    );
    if report.shards_dropped > 0 {
        println!(
            "dropped:   {} record(s) outside the contiguous prefix",
            report.shards_dropped
        );
    }
    println!(
        "readable:  {} of {} bytes ({} lost past the tear)",
        report.data_end,
        report.data_end + report.bytes_lost,
        report.bytes_lost,
    );
    if let Some((s, e, off)) = report.last_valid {
        println!("last structurally-valid record: particles {s}..{e} at byte offset {off}");
    }
    let out = match args.get("output") {
        Some(o) => PathBuf::from(o),
        None => PathBuf::from(format!("{input}.salvaged")),
    };
    let index = reader.export_salvaged(&out)?;
    println!(
        "wrote {} ({} shards, footer reconstructed; try `nblc inspect {}`)",
        out.display(),
        index.entries.len(),
        out.display(),
    );
    Ok(())
}

fn cmd_list_codecs(args: &Args) -> Result<()> {
    args.expect_known(&[])?;
    println!("{:<12} {:<8} description", "codec", "reorders");
    for e in registry::entries() {
        let name = if e.aliases.is_empty() {
            e.name.to_string()
        } else {
            format!("{} ({})", e.name, e.aliases.join(", "))
        };
        println!(
            "{:<12} {:<8} {}",
            name,
            if e.reorders { "yes" } else { "no" },
            e.description
        );
        for p in e.params {
            println!(
                "             --method {}:{}=<{}>  default {}  {}",
                e.name,
                p.key,
                p.kind.describe(),
                p.default,
                p.help
            );
        }
    }
    println!("\nspec syntax: name:key=val,key=val   e.g. sz_lv_rx:segment=4096");
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    args.expect_known(&[])?;
    let [orig_path, recon_path] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: analyze <orig.snap> <recon.snap>"));
    };
    let orig = read_snapshot(Path::new(orig_path))?;
    let recon = read_snapshot(Path::new(recon_path))?;
    println!("{:>4} {:>12} {:>12} {:>10}", "fld", "max_err", "NRMSE", "PSNR");
    for f in 0..6 {
        let s = ErrorStats::compute(&orig.fields[f], &recon.fields[f])?;
        println!(
            "{:>4} {:>12.3e} {:>12.3e} {:>9.2}dB",
            FIELD_NAMES[f], s.max_err, s.nrmse, s.psnr
        );
    }
    let psnr = ErrorStats::snapshot_psnr(&orig, &recon)?;
    println!("overall PSNR: {psnr:.2} dB");
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    args.expect_known(&[
        "config", "threads", "simd", "stream", "keyframe-every", "steps", "dt",
    ])?;
    for temporal_only in ["keyframe-every", "steps", "dt"] {
        if args.get(temporal_only).is_some() && !args.has("stream") {
            return Err(Error::invalid(format!(
                "--{temporal_only} only applies to `pipeline --stream`"
            )));
        }
    }
    let cfg_path = args.get_or("config", "nblc.toml");
    let doc = ConfigDoc::from_file(Path::new(&cfg_path))?;
    let mut settings = PipelineSettings::from_doc(&doc)?;
    // --threads overrides the config's per-worker budget (0 = auto).
    settings.threads = args.get_parse("threads", settings.threads)?;
    // Kernel backend: `--simd` flag > config's `simd` key > NBLC_SIMD.
    if args.get("simd").is_none() {
        let mode = nblc::kernels::SimdMode::parse(&settings.simd).ok_or_else(|| {
            Error::Config(format!("'simd' must be off|auto|force, got '{}'", settings.simd))
        })?;
        nblc::kernels::set_mode(mode);
    }
    let kern = simd_kernels(args)?;
    println!("kernel backend: {}", kern.label);
    let kind = dataset_kind(&settings.dataset)?;
    let n = if settings.particles > 0 {
        settings.particles
    } else {
        nblc::data::default_n(kind)
    };
    if args.has("stream") {
        return cmd_pipeline_stream(args, &doc, &settings, kind, n);
    }
    println!("generating {} snapshot (n={n})...", kind.name());
    let snap = generate(kind, n, nblc::bench::BENCH_SEED);

    // Spatial layout: globally Morton-order the snapshot and cut shard
    // boundaries on octree-cell edges, so the archive's footer carries
    // a bbox index that region queries can prune against. Done before
    // spec resolution: codec routing must see the snapshot it will
    // actually compress (the permuted one).
    let mut spatial_cuts: Vec<usize> = Vec::new();
    let mut spatial_cfg: Option<SpatialInsitu> = None;
    let mut initial_layout: Option<Vec<Shard>> = None;
    let snap = if settings.layout == "spatial" {
        let plan = plan_spatial(
            &snap,
            settings.shards,
            settings.spatial_bits,
            &ExecCtx::resolve(settings.threads),
        )?;
        println!(
            "layout: spatial ({} shards cut on Morton cell edges, {} bits/axis, {} interior cuts)",
            plan.layout.len(),
            plan.bits,
            plan.cuts.len(),
        );
        spatial_cuts = plan.cuts.clone();
        spatial_cfg = Some(SpatialInsitu {
            bits: plan.bits,
            seg: settings.spatial_seg,
            keys: std::sync::Arc::clone(&plan.keys),
        });
        initial_layout = Some(plan.layout.clone());
        plan.snapshot
    } else {
        snap
    };

    // An explicit codec spec pins the compressor; `method = "auto..."`
    // runs the sampled planner; otherwise the mode (plus the §V-C
    // scheduler when auto_route is on) picks it.
    let auto_target = match &settings.method {
        Some(m) => parse_auto(m)?,
        None => None,
    };
    let spec = match (&settings.method, auto_target) {
        (Some(_), Some(target)) => {
            let stats = SnapshotStats::collect(&snap);
            let (name, plan) = registry::plan_auto(&stats, &settings.quality, target)?;
            print_plan(&plan);
            registry::canonical(&name)?
        }
        (Some(m), None) => {
            let canonical = registry::canonical(m)?;
            println!("pipeline codec: {canonical}");
            canonical
        }
        (None, _) => {
            let mode = if settings.auto_route {
                let routed = choose_compressor(&snap, settings.mode);
                if routed != settings.mode {
                    println!(
                        "scheduler: '{}' overridden to '{}' (orderly coordinate detected, par.V-C)",
                        settings.mode.name(),
                        routed.name()
                    );
                }
                routed
            } else {
                settings.mode
            };
            // Canonicalize (resolving `mode:` to the concrete codec +
            // full parameter set) so an archive sink records a spec
            // that survives future changes to the mode mapping.
            registry::canonical(&mode.spec())?
        }
    };

    let factory = registry::factory(&spec)?;
    let make_sink = || {
        if let Some(out) = &settings.output {
            Sink::Archive {
                path: PathBuf::from(out),
                spec: spec.clone(),
            }
        } else if settings.sim_procs > 0 {
            Sink::Model {
                model: GpfsModel::default(),
                procs: settings.sim_procs,
            }
        } else {
            Sink::Null
        }
    };
    let run = |layout: Option<Vec<Shard>>, final_round: bool| {
        // A rebalancing round 1 only exists to collect cost counters;
        // don't stream the whole archive to disk twice when an output
        // path is configured — round 2 writes the real file.
        let sink = if !final_round && settings.output.is_some() {
            Sink::Null
        } else {
            make_sink()
        };
        run_insitu(
            &snap,
            &InsituConfig {
                shards: settings.shards,
                layout,
                workers: settings.workers,
                threads: settings.threads,
                queue_depth: settings.queue_depth,
                quality: settings.quality.clone(),
                factory: factory.clone(),
                sink,
                spatial: spatial_cfg.clone(),
                max_retries: settings.max_retries,
                sink_fault: None,
            },
        )
    };
    let print_report = |label: &str, report: &InsituReport| {
        println!(
            "pipeline {label}: ratio {:.2}, compress rate {}, wall {}, sink {}, stalls src={} sink={}",
            report.ratio,
            humansize::rate(report.compress_rate),
            humansize::secs(report.wall_secs),
            humansize::secs(report.sink_secs),
            report.source_stalls,
            report.sink_stalls,
        );
        if report.retries > 0 {
            println!(
                "pipeline {label}: {} task retr{} recovered transient faults",
                report.retries,
                if report.retries == 1 { "y" } else { "ies" },
            );
        }
    };
    // A degraded run (shards missing even after retries) is a typed
    // failure with a non-zero exit: the archive — when one was being
    // written — has no footer, but remains recoverable via
    // `nblc salvage`.
    let check_degraded = |report: &InsituReport| -> Result<()> {
        if report.failures.is_empty() {
            return Ok(());
        }
        for f in &report.failures {
            eprintln!(
                "pipeline failure: rank {} [{}..{}] at stage '{}' after {} attempt(s): {}",
                f.rank, f.start, f.end, f.stage, f.attempts, f.error,
            );
        }
        Err(Error::PartialFailure {
            failed: report.failures.len(),
            total: settings.shards,
            retries: report.retries,
        })
    };

    let mut report = run(initial_layout.clone(), !settings.rebalance)?;
    print_report("round 1", &report);
    check_degraded(&report)?;
    if settings.rebalance {
        // Feed the observed per-shard cost counters (the same numbers
        // the v3 footer records) back into the boundary splitter and
        // re-run; the archive is written by this final round. A spatial
        // layout recuts only along the Morton cell edges so the footer
        // index stays aligned with the octree cells.
        let costs = report.cost_per_particle();
        let layout2 = if spatial_cfg.is_some() {
            rebalance_aligned(&report.layout, &costs, &spatial_cuts)
        } else {
            rebalance(&report.layout, &costs)
        };
        println!("rebalance: shard boundaries recut from round-1 cost counters");
        report = run(Some(layout2), true)?;
        print_report("round 2 (rebalanced)", &report);
        check_degraded(&report)?;
    }
    if let Some(out) = &settings.output {
        let shards_written = report
            .shard_index
            .as_ref()
            .map(|i| i.entries.len())
            .unwrap_or(0);
        println!("archive: wrote sharded v3 archive to {out} ({shards_written} shards; try `nblc inspect {out}`)");
    }
    Ok(())
}

/// The `--stream` arm of `pipeline`: compress a generated leapfrog
/// time series into one temporal keyframe+delta archive (see
/// [`run_insitu_stream`]).
fn cmd_pipeline_stream(
    args: &Args,
    doc: &ConfigDoc,
    settings: &PipelineSettings,
    kind: DatasetKind,
    n: usize,
) -> Result<()> {
    let mut temporal = TemporalSettings::from_doc(doc)?;
    // Flags override the config's [temporal] section.
    temporal.keyframe_interval = args.get_parse("keyframe-every", temporal.keyframe_interval)?;
    temporal.steps = args.get_parse("steps", temporal.steps)?;
    temporal.dt = args.get_parse("dt", temporal.dt)?;
    if temporal.steps == 0 {
        return Err(Error::invalid("--steps must be >= 1"));
    }
    if !temporal.dt.is_finite() || temporal.dt < 0.0 {
        return Err(Error::invalid("--dt must be a finite float >= 0"));
    }
    let Some(out) = &settings.output else {
        return Err(Error::Config(
            "stream mode always writes an archive (the chain lives in its \
             footer): set [pipeline] output"
                .into(),
        ));
    };
    if settings.layout == "spatial" {
        return Err(Error::Config(
            "stream mode requires layout = \"cost\": delta residuals are \
             particle-index-aligned, which a per-timestep Morton permutation \
             would break"
                .into(),
        ));
    }
    if settings.rebalance {
        return Err(Error::Config(
            "stream mode does not rebalance: the chain's shard layout must \
             stay fixed across timesteps"
                .into(),
        ));
    }
    // Codec: an explicit method or the mode mapping. Auto planning is
    // single-snapshot and not offered for streams, and the §V-C
    // auto-route is skipped — it may pick an R-index codec, which
    // stream mode rejects anyway.
    let spec = match &settings.method {
        Some(m) if m == "auto" || m.starts_with("auto:") => {
            return Err(Error::Config(
                "stream mode takes an explicit method or mode, not auto planning".into(),
            ));
        }
        Some(m) => registry::canonical(m)?,
        None => registry::canonical(&settings.mode.spec())?,
    };
    println!("stream codec: {spec}");
    println!(
        "generating {} time series (n={n}, {} steps, dt={})...",
        kind.name(),
        temporal.steps,
        temporal.dt,
    );
    let series = generate_series(kind, n, nblc::bench::BENCH_SEED, temporal.steps, temporal.dt);
    let report = run_insitu_stream(
        &series,
        &StreamConfig {
            shards: settings.shards,
            threads: settings.threads,
            quality: settings.quality.clone(),
            factory: registry::factory(&spec)?,
            path: PathBuf::from(out),
            spec: spec.clone(),
            temporal: nblc::temporal::TemporalConfig::new(temporal.keyframe_interval)?,
            dt: temporal.dt,
            max_retries: settings.max_retries,
        },
    )?;
    let keyframes = report.steps.iter().filter(|s| s.keyframe).count();
    println!(
        "stream: {} timesteps ({} keyframes at interval {}), ratio {:.2}, wall {}",
        report.steps.len(),
        keyframes,
        temporal.keyframe_interval,
        report.ratio,
        humansize::secs(report.wall_secs),
    );
    if let Some(r) = report.delta_vs_keyframe() {
        println!("stream: delta steps {r:.2}x smaller than keyframes on average");
    }
    if report.retries > 0 {
        println!(
            "stream: {} task retr{} recovered transient faults",
            report.retries,
            if report.retries == 1 { "y" } else { "ies" },
        );
    }
    println!(
        "archive: wrote temporal stream archive to {out} ({} shards across {} timesteps; \
         try `nblc inspect {out}`)",
        report.shard_index.entries.len(),
        report.steps.len(),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(&[
        "config",
        "addr",
        "cache_mb",
        "max_inflight",
        "queue_timeout_ms",
        "decode_budget_ms",
        "threads",
        "simd",
    ])?;
    // Backend selection must land before the server builds its contexts.
    let kern = simd_kernels(args)?;
    if args.positionals.is_empty() {
        return Err(Error::invalid(
            "usage: serve <archive.nblc>... [--addr host:port]",
        ));
    }
    let mut settings = ServeSettings::default();
    if let Some(cfg_path) = args.get("config") {
        let doc = ConfigDoc::from_file(Path::new(cfg_path))?;
        settings = ServeSettings::from_doc(&doc)?;
    }
    // Flags override the config's [serve] section.
    if let Some(addr) = args.get("addr") {
        settings.addr = addr.to_string();
    }
    settings.cache_mb = args.get_parse("cache_mb", settings.cache_mb)?;
    settings.max_inflight = args.get_parse("max_inflight", settings.max_inflight)?;
    settings.queue_timeout_ms = args.get_parse("queue_timeout_ms", settings.queue_timeout_ms)?;
    settings.decode_budget_ms = args.get_parse("decode_budget_ms", settings.decode_budget_ms)?;
    settings.threads = args.get_parse("threads", settings.threads)?;
    let cfg = ServeConfig {
        addr: settings.addr,
        cache_mb: settings.cache_mb,
        max_inflight: settings.max_inflight,
        queue_timeout_ms: settings.queue_timeout_ms,
        decode_budget_ms: settings.decode_budget_ms,
        threads: settings.threads,
    };
    let paths: Vec<PathBuf> = args.positionals.iter().map(PathBuf::from).collect();
    let server = Server::bind(&cfg, &paths)?;
    println!(
        "serving {} on {} (cache {} MiB, max_inflight {}, queue timeout {} ms, kernels {})",
        server.archive_names().join(", "),
        server.local_addr(),
        cfg.cache_mb,
        cfg.max_inflight,
        cfg.queue_timeout_ms,
        kern.label,
    );
    if server.salvaged_shards() > 0 {
        println!(
            "warning: serving {} salvaged shard(s) from footer-less archive(s); \
             run `nblc salvage` to materialize intact copies",
            server.salvaged_shards(),
        );
    }
    install_stop_handler();
    // Watcher: the signal handler only flips an atomic (async-signal-
    // safe); this thread turns it into a server stop + a throwaway
    // connection, because glibc installs SIGTERM with SA_RESTART and a
    // blocking accept() would otherwise never notice.
    let stop = server.stop_flag();
    let addr = server.local_addr();
    std::thread::spawn(move || loop {
        if STOP_SIGNAL.load(Ordering::SeqCst) {
            stop.store(true, Ordering::SeqCst);
            let _ = std::net::TcpStream::connect(addr);
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    server.run();
    println!(
        "shutdown: drained {} connection(s) after their in-flight request completed",
        server.drained_connections(),
    );
    Ok(())
}

/// Set on SIGTERM/SIGINT; polled by the serve watcher thread.
static STOP_SIGNAL: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_stop_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_stop(_sig: i32) {
        STOP_SIGNAL.store(true, Ordering::SeqCst);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_stop as usize);
        signal(SIGINT, on_stop as usize);
    }
}

#[cfg(not(unix))]
fn install_stop_handler() {}

fn cmd_get(args: &Args) -> Result<()> {
    args.expect_known(&["addr", "particles", "region", "timestep", "out", "stats", "retries"])?;
    let addr = args.get_or("addr", "127.0.0.1:7117");
    let mut client = ServeClient::connect(addr.as_str())?;
    if args.has("stats") {
        print!("{}", client.stats()?.render());
        return Ok(());
    }
    // Archive basename; empty selects the daemon's only archive.
    let archive = args.positionals.first().map(String::as_str).unwrap_or("");
    let selectors = ["particles", "region", "timestep"]
        .iter()
        .filter(|f| args.get(f).is_some())
        .count();
    if selectors > 1 {
        return Err(Error::invalid(
            "give at most one of --particles, --region, --timestep (index \
             range, box, and chain step are distinct queries)",
        ));
    }
    let region = match args.get("region") {
        Some(s) => Some(parse_region(s)?),
        None => None,
    };
    let range = match args.get("particles") {
        Some(s) => Some(parse_particles(s)?),
        None => None,
    };
    let timestep: Option<u64> = match args.get("timestep") {
        Some(s) => Some(s.parse().map_err(|_| {
            Error::invalid(format!("--timestep: cannot parse '{s}'"))
        })?),
        None => None,
    };
    let retries: usize = args.get_parse("retries", 0)?;
    let t = Timer::start();
    let reply = match (&region, timestep) {
        (Some(r), _) => client.get_region(archive, r.min, r.max)?,
        (None, Some(ts)) => client.get_timestep(archive, ts)?,
        (None, None) => client.get_with_retry(archive, range, retries)?,
    };
    match reply {
        GetReply::Data(d) => {
            let secs = t.secs();
            if let Some(out) = args.get("out") {
                write_snapshot(&d.snapshot, Path::new(out))?;
            }
            if d.region {
                println!(
                    "got {} particles in region in {} ({} shards decoded, {} pruned, {} cache hits)",
                    d.snapshot.len(),
                    humansize::secs(secs),
                    d.shards_touched,
                    d.shards_pruned,
                    d.cache_hits,
                );
            } else if let Some(ts) = timestep {
                println!(
                    "got timestep {ts}: {} particles [{}..{}] in {} ({} shards decoded, {} cache hits)",
                    d.snapshot.len(),
                    d.particle_start,
                    d.particle_end,
                    humansize::secs(secs),
                    d.shards_touched,
                    d.cache_hits,
                );
            } else {
                println!(
                    "got {} particles [{}..{}] in {} ({} shards, {} cache hits, {})",
                    d.snapshot.len(),
                    d.particle_start,
                    d.particle_end,
                    humansize::secs(secs),
                    d.shards_touched,
                    d.cache_hits,
                    if d.exact {
                        "exact range"
                    } else {
                        "whole overlapping shards"
                    },
                );
            }
        }
        GetReply::Busy(b) => {
            return Err(Error::Pipeline(format!(
                "server busy after {} attempt(s): {}/{} requests in flight \
                 (est cost {:.1} ms in flight, budget {:.1} ms); retry later or raise --retries",
                retries + 1,
                b.inflight,
                b.max_inflight,
                b.inflight_cost_nanos as f64 / 1e6,
                b.budget_nanos as f64 / 1e6,
            )));
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.expect_known(&["simd"])?;
    println!("nblc {}", env!("CARGO_PKG_VERSION"));
    let kern = simd_kernels(args)?;
    println!("kernels: {} (selected; --simd off|auto|force or NBLC_SIMD overrides)", kern.label);
    let available: Vec<&str> =
        nblc::kernels::Kernels::variants().iter().map(|k| k.label).collect();
    println!("kernel backends available: {}", available.join(", "));
    for kind in [DatasetKind::Hacc, DatasetKind::Amdf] {
        println!(
            "dataset {}: default n = {}",
            kind.name(),
            nblc::data::default_n(kind)
        );
    }
    // Quick sanity that every registered codec still builds.
    let ok = registry::entries()
        .iter()
        .filter(|e| registry::build_str(e.name).is_ok())
        .count();
    println!("codecs: {}/{} registered specs build", ok, registry::entries().len());
    Ok(())
}
