//! `nblc` — the launcher / leader entrypoint.
//!
//! Subcommands:
//!   gen        generate a synthetic snapshot to a file
//!   compress   compress a snapshot file with a named method
//!   decompress decompress a bundle back to a snapshot file
//!   analyze    distortion report (max err / NRMSE / PSNR per field)
//!   pipeline   run the in-situ pipeline from a config file
//!   info       print dataset / artifact / runtime diagnostics

use nblc::cli::Args;
use nblc::compressors::{by_name, mode_compressor};
use nblc::config::{ConfigDoc, PipelineSettings};
use nblc::coordinator::pipeline::{run_insitu, CompressorFactory, InsituConfig, Sink};
use nblc::coordinator::{choose_compressor, GpfsModel};
use nblc::data::io::{read_snapshot, write_snapshot};
use nblc::data::{generate, DatasetKind};
use nblc::error::{Error, Result};
use nblc::metrics::ErrorStats;
use nblc::snapshot::FIELD_NAMES;
use nblc::util::humansize;
use nblc::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const HELP: &str = "\
nblc — single-snapshot lossy compression for N-body simulations

USAGE: nblc <command> [flags]

COMMANDS:
  gen        --dataset hacc|amdf --n <count> --seed <u64> --out <file>
  compress   <in.snap> <out.nblc> --method <name> [--eb 1e-4]
  decompress <in.nblc> <out.snap> --method <name>
  analyze    <orig.snap> <recon.snap>
  pipeline   --config <file.toml>
  info       [--artifacts <dir>]

Methods: gzip cpc2000 fpzip isabela zfp sz sz_lv sz_lv_rx sz_lv_prx sz_cpc2000
Modes (pipeline): best_speed best_tradeoff best_compression
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        print!("{HELP}");
        return;
    }
    let parsed = match Args::parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&parsed) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "gen" => cmd_gen(args),
        "compress" => cmd_compress(args),
        "decompress" => cmd_decompress(args),
        "analyze" => cmd_analyze(args),
        "pipeline" => cmd_pipeline(args),
        "info" => cmd_info(args),
        other => Err(Error::invalid(format!(
            "unknown command '{other}' (try --help)"
        ))),
    }
}

fn dataset_kind(name: &str) -> Result<DatasetKind> {
    match name {
        "hacc" => Ok(DatasetKind::Hacc),
        "amdf" => Ok(DatasetKind::Amdf),
        _ => Err(Error::invalid(format!("unknown dataset '{name}'"))),
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    args.expect_known(&["dataset", "n", "seed", "out"])?;
    let kind = dataset_kind(&args.get_or("dataset", "hacc"))?;
    let n: usize = args.get_parse("n", 1_000_000)?;
    let seed: u64 = args.get_parse("seed", nblc::bench::BENCH_SEED)?;
    let out = PathBuf::from(args.get_or("out", "snapshot.snap"));
    let t = Timer::start();
    let snap = generate(kind, n, seed);
    write_snapshot(&snap, &out)?;
    println!(
        "generated {} ({} particles, {}) in {} -> {}",
        kind.name(),
        snap.len(),
        humansize::bytes(snap.total_bytes() as u64),
        humansize::secs(t.secs()),
        out.display()
    );
    Ok(())
}

/// Bundle container: magic, method, eb, per-field streams.
mod bundlefile {
    use super::*;
    use nblc::snapshot::{CompressedField, CompressedSnapshot};
    use nblc::util::varint::{get_uvarint, put_uvarint};
    use std::io::{Read, Write};

    const MAGIC: &[u8; 8] = b"NBLCBNDL";

    pub fn write(bundle: &CompressedSnapshot, path: &Path) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        let mut head = Vec::new();
        put_uvarint(&mut head, bundle.compressor.len() as u64);
        head.extend_from_slice(bundle.compressor.as_bytes());
        head.extend_from_slice(&bundle.eb_rel.to_le_bytes());
        put_uvarint(&mut head, bundle.n as u64);
        put_uvarint(&mut head, bundle.fields.len() as u64);
        w.write_all(&head)?;
        for f in &bundle.fields {
            let mut fh = Vec::new();
            put_uvarint(&mut fh, f.name.len() as u64);
            fh.extend_from_slice(f.name.as_bytes());
            put_uvarint(&mut fh, f.n as u64);
            put_uvarint(&mut fh, f.bytes.len() as u64);
            w.write_all(&fh)?;
            w.write_all(&f.bytes)?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn read(path: &Path) -> Result<CompressedSnapshot> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..8] != MAGIC {
            return Err(Error::Format {
                expected: "NBLCBNDL".into(),
                found: "bad magic".into(),
            });
        }
        let mut pos = 8usize;
        let name_len = get_uvarint(&bytes, &mut pos)? as usize;
        let compressor = String::from_utf8(bytes[pos..pos + name_len].to_vec())
            .map_err(|_| Error::corrupt("bundle method name not utf8"))?;
        pos += name_len;
        let eb_rel = f64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let n = get_uvarint(&bytes, &mut pos)? as usize;
        let n_fields = get_uvarint(&bytes, &mut pos)? as usize;
        let mut fields = Vec::with_capacity(n_fields);
        for _ in 0..n_fields {
            let nl = get_uvarint(&bytes, &mut pos)? as usize;
            let name = String::from_utf8(bytes[pos..pos + nl].to_vec())
                .map_err(|_| Error::corrupt("field name not utf8"))?;
            pos += nl;
            let fn_ = get_uvarint(&bytes, &mut pos)? as usize;
            let bl = get_uvarint(&bytes, &mut pos)? as usize;
            if pos + bl > bytes.len() {
                return Err(Error::corrupt("bundle truncated"));
            }
            fields.push(CompressedField {
                name,
                n: fn_,
                bytes: bytes[pos..pos + bl].to_vec(),
            });
            pos += bl;
        }
        Ok(CompressedSnapshot {
            compressor,
            eb_rel,
            fields,
            n,
        })
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    args.expect_known(&["method", "eb"])?;
    let [input, output] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: compress <in.snap> <out.nblc>"));
    };
    let method = args.get_or("method", "sz_lv");
    let eb: f64 = args.get_parse("eb", 1e-4)?;
    let comp =
        by_name(&method).ok_or_else(|| Error::invalid(format!("unknown method '{method}'")))?;
    let snap = read_snapshot(Path::new(input))?;
    let t = Timer::start();
    let bundle = comp.compress(&snap, eb)?;
    let secs = t.secs();
    bundlefile::write(&bundle, Path::new(output))?;
    println!(
        "{method}: {} -> {} (ratio {:.2}, {} at {})",
        humansize::bytes(bundle.original_bytes() as u64),
        humansize::bytes(bundle.compressed_bytes() as u64),
        bundle.compression_ratio(),
        humansize::secs(secs),
        humansize::rate(bundle.original_bytes() as f64 / secs),
    );
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    args.expect_known(&["method"])?;
    let [input, output] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: decompress <in.nblc> <out.snap>"));
    };
    let bundle = bundlefile::read(Path::new(input))?;
    let method = args.get_or("method", &bundle.compressor);
    let comp =
        by_name(&method).ok_or_else(|| Error::invalid(format!("unknown method '{method}'")))?;
    let t = Timer::start();
    let snap = comp.decompress(&bundle)?;
    write_snapshot(&snap, Path::new(output))?;
    println!(
        "decompressed {} particles in {} ({})",
        snap.len(),
        humansize::secs(t.secs()),
        if comp.reorders() {
            "R-index particle order"
        } else {
            "original particle order"
        }
    );
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    args.expect_known(&[])?;
    let [orig_path, recon_path] = args.positionals.as_slice() else {
        return Err(Error::invalid("usage: analyze <orig.snap> <recon.snap>"));
    };
    let orig = read_snapshot(Path::new(orig_path))?;
    let recon = read_snapshot(Path::new(recon_path))?;
    println!("{:>4} {:>12} {:>12} {:>10}", "fld", "max_err", "NRMSE", "PSNR");
    for f in 0..6 {
        let s = ErrorStats::compute(&orig.fields[f], &recon.fields[f])?;
        println!(
            "{:>4} {:>12.3e} {:>12.3e} {:>9.2}dB",
            FIELD_NAMES[f], s.max_err, s.nrmse, s.psnr
        );
    }
    let psnr = ErrorStats::snapshot_psnr(&orig, &recon)?;
    println!("overall PSNR: {psnr:.2} dB");
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    args.expect_known(&["config"])?;
    let cfg_path = args.get_or("config", "nblc.toml");
    let doc = ConfigDoc::from_file(Path::new(&cfg_path))?;
    let settings = PipelineSettings::from_doc(&doc)?;
    let kind = dataset_kind(&settings.dataset)?;
    let n = if settings.particles > 0 {
        settings.particles
    } else {
        nblc::data::default_n(kind)
    };
    println!("generating {} snapshot (n={n})...", kind.name());
    let snap = generate(kind, n, nblc::bench::BENCH_SEED);

    let mode = if settings.auto_route {
        let routed = choose_compressor(&snap, settings.mode);
        if routed != settings.mode {
            println!(
                "scheduler: '{}' overridden to '{}' (orderly coordinate detected, par.V-C)",
                settings.mode.name(),
                routed.name()
            );
        }
        routed
    } else {
        settings.mode
    };

    let factory: CompressorFactory = Arc::new(move || mode_compressor(mode));
    let sink = if settings.sim_procs > 0 {
        Sink::Model {
            model: GpfsModel::default(),
            procs: settings.sim_procs,
        }
    } else {
        Sink::Null
    };
    let report = run_insitu(
        &snap,
        &InsituConfig {
            shards: settings.shards,
            workers: settings.workers,
            queue_depth: settings.queue_depth,
            eb_rel: settings.eb_rel,
            factory,
            sink,
        },
    )?;
    println!(
        "pipeline done: ratio {:.2}, compress rate {}, wall {}, sink {}, stalls src={} sink={}",
        report.ratio,
        humansize::rate(report.compress_rate),
        humansize::secs(report.wall_secs),
        humansize::secs(report.sink_secs),
        report.source_stalls,
        report.sink_stalls,
    );
    if settings.use_pjrt {
        println!("(note: use_pjrt requested; PJRT quantizer engages in the sz_lv path when artifacts are present)");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts"])?;
    println!("nblc {}", env!("CARGO_PKG_VERSION"));
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(nblc::runtime::default_artifacts_dir);
    match nblc::runtime::Runtime::load(&dir) {
        Ok(rt) => println!(
            "artifacts: {} (platform {})",
            rt.dir().display(),
            rt.platform()
        ),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    for kind in [DatasetKind::Hacc, DatasetKind::Amdf] {
        println!(
            "dataset {}: default n = {}",
            kind.name(),
            nblc::data::default_n(kind)
        );
    }
    Ok(())
}
