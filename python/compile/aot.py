"""AOT lowering: JAX/Pallas graphs -> HLO text -> artifacts/.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out ../artifacts [--n 262144]
Writes one .hlo.txt per graph plus `manifest.txt`:
    name<TAB>file<TAB>n<TAB>inputs
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Elements per AOT graph execution (the Rust runtime pads the tail).
DEFAULT_N = 1 << 18


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def graphs(n):
    f = jax.ShapeDtypeStruct((n,), jnp.float32)
    i = jax.ShapeDtypeStruct((n,), jnp.int32)
    s = jax.ShapeDtypeStruct((1,), jnp.float32)
    return {
        "quantize_lv": (model.quantize_lv, (f, s, s), "x,x0,inv_step"),
        "quantize_lcf": (model.quantize_lcf, (f, s, s), "x,x0,inv_step"),
        "dequantize_lv": (model.dequantize_lv, (i, s, s), "codes,x0,step"),
        "dequantize_lcf": (model.dequantize_lcf, (i, s, s), "codes,x0,step"),
        "field_metrics": (model.field_metrics, (f, f), "x,y"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, (fn, specs, inputs) in graphs(args.n).items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as fh:
            fh.write(text)
        manifest.append(f"{name}\t{fname}\t{args.n}\t{inputs}")
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"wrote manifest.txt ({len(manifest)} graphs, n={args.n})")


if __name__ == "__main__":
    main()
