"""L2 JAX model: the compute graphs the Rust coordinator executes via
PJRT. Each graph composes the L1 Pallas kernels with the padding /
prefix-sum plumbing that XLA fuses around them.

Graphs (all over a fixed element count ``N``, fixed at AOT time; the
Rust runtime feeds padded blocks):

* ``quantize_lv / quantize_lcf``:  x[N], x0[1], inv_step[1] -> codes i32[N]
* ``dequantize_lv / dequantize_lcf``: codes i32[N], x0[1], step[1] -> x[N]
* ``field_metrics``: x[N], y[N] -> (sse[1], max_err[1])

Python never runs on the request path: `aot.py` lowers these once to
HLO text in `artifacts/`.
"""

import jax
import jax.numpy as jnp

from .kernels import quantize as kq


def quantize_lv(x, x0, inv_step):
    """SZ-LV quantization codes (order-1 lattice differences)."""
    return kq.quantize_codes(x, x0, inv_step, order=1, block=_block_for(x.shape[0]))


def quantize_lcf(x, x0, inv_step):
    """SZ-LCF quantization codes (order-2 lattice differences)."""
    return kq.quantize_codes(x, x0, inv_step, order=2, block=_block_for(x.shape[0]))


def dequantize_lv(codes, x0, step):
    """Inverse of `quantize_lv`: prefix-sum then lattice evaluation."""
    k = jnp.cumsum(codes, dtype=jnp.int64 if jax.config.x64_enabled else jnp.int32)
    return kq.dequantize_values(
        k.astype(jnp.int32), x0, step, block=_block_for(codes.shape[0])
    )


def dequantize_lcf(codes, x0, step):
    """Inverse of `quantize_lcf`: double prefix-sum then lattice."""
    dtype = jnp.int64 if jax.config.x64_enabled else jnp.int32
    k = jnp.cumsum(jnp.cumsum(codes, dtype=dtype), dtype=dtype)
    return kq.dequantize_values(
        k.astype(jnp.int32), x0, step, block=_block_for(codes.shape[0])
    )


def field_metrics(x, y):
    """(sse, max_err) over a field pair, Pallas partials + jnp reduce."""
    sse_p, max_p = kq.metrics_partials(x, y, block=_block_for(x.shape[0]))
    return jnp.sum(sse_p, keepdims=True), jnp.max(max_p, keepdims=True)


def _block_for(n):
    """Largest kernel block that divides n (tests use small n; the AOT
    graphs use n = a multiple of the full kernel block)."""
    b = min(kq.BLOCK, n)
    while n % b != 0:
        b -= 1
    return max(b, 1)
