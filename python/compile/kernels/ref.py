"""Pure-jnp oracle for the L1 quantization kernels.

This is the correctness reference the Pallas kernels (and, via stream
compatibility, the Rust native quantizer) are validated against in
pytest. Everything here is straight-line jnp with no Pallas."""

import jax.numpy as jnp


def lattice_k(x, x0, inv_step):
    """Lattice index of every element (f32 math, matching the kernel)."""
    return jnp.round((x - x0) * inv_step).astype(jnp.int32)


def quantize_codes_ref(x, x0, inv_step, order):
    """Difference codes of the lattice indices (order 1 = LV, 2 = LCF)."""
    k = lattice_k(x, x0, inv_step)
    if order == 1:
        km1 = jnp.concatenate([k[:1], k[:-1]])
        return k - km1
    if order == 2:
        km1 = jnp.concatenate([k[:1], k[:-1]])
        km2 = jnp.concatenate([km1[:1], km1[:-1]])
        return k - 2 * km1 + km2
    raise ValueError(f"order must be 1 or 2, got {order}")


def reconstruct_k_ref(codes, order):
    """Invert the difference coding back to lattice indices."""
    if order == 1:
        return jnp.cumsum(codes)
    if order == 2:
        return jnp.cumsum(jnp.cumsum(codes))
    raise ValueError(f"order must be 1 or 2, got {order}")


def dequantize_ref(codes, x0, step, order):
    """Reconstruct values from codes."""
    k = reconstruct_k_ref(codes, order)
    return (x0 + k.astype(jnp.float32) * step).astype(jnp.float32)


def metrics_ref(x, y):
    """(sse, max abs err) in f32."""
    d = (x - y).astype(jnp.float32)
    return jnp.sum(d * d), jnp.max(jnp.abs(d))
