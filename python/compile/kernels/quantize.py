"""L1 Pallas kernels: error-bounded lattice quantization for SZ-style
compression.

The SZ prediction loop is sequential (predictions consume reconstructed
values); nblc uses the parallel lattice reformulation (DESIGN.md par.3):

    k_i    = round((x_i - x0) / (2*eb))          (lattice index)
    LV:    q_i = k_i - k_{i-1}                    (order-1 difference)
    LCF:   q_i = k_i - 2 k_{i-1} + k_{i-2}        (order-2 difference)

which is elementwise + a 1-2 element halo — a perfect Pallas shape: each
grid step streams one block from HBM to VMEM, loads the halo elements of
the previous block, and emits int32 codes. `interpret=True` everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls; real-TPU
lowering would keep the same BlockSpecs (see DESIGN.md
par.Hardware-Adaptation for the VMEM/roofline analysis).

All kernels treat scalars (anchor, 1/step, step) as (1,)-shaped operands
so the same HLO graph serves any bound.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size per grid step: 2^15 f32 elements = 128 KiB in + 128 KiB out
# per step, comfortably inside a TPU core's ~16 MiB VMEM with double
# buffering; on CPU-interpret it just bounds working-set size.
BLOCK = 1 << 15


def _halo_spec(block):
    """BlockSpec for a 1-element halo: element i*block - 1, clamped to 0.

    For grid step 0 the clamp yields element 0 == the anchor, making the
    first code k_0 - k_0 = 0 by construction — exactly the stream spec.
    """
    return pl.BlockSpec((1,), lambda i: (jnp.maximum(i * block - 1, 0),))


def _halo2_spec(block):
    """Halo at element i*block - 2 (clamped), for the order-2 model."""
    return pl.BlockSpec((1,), lambda i: (jnp.maximum(i * block - 2, 0),))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda i: (0,))


def _k(x, x0, inv_step):
    """Lattice index of x (f32 math; see DESIGN.md on the f32 domain)."""
    return jnp.round((x - x0) * inv_step).astype(jnp.int32)


def _quantize_lv_kernel(x_ref, prev_ref, x0_ref, inv_ref, o_ref):
    x0 = x0_ref[0]
    inv = inv_ref[0]
    k = _k(x_ref[...], x0, inv)
    k_prev = _k(prev_ref[...], x0, inv)  # shape (1,)
    km1 = jnp.concatenate([k_prev, k[:-1]])
    o_ref[...] = k - km1


def _quantize_lcf_kernel(x_ref, prev_ref, prev2_ref, x0_ref, inv_ref, o_ref):
    x0 = x0_ref[0]
    inv = inv_ref[0]
    k = _k(x_ref[...], x0, inv)
    k_prev = _k(prev_ref[...], x0, inv)
    k_prev2 = _k(prev2_ref[...], x0, inv)
    km1 = jnp.concatenate([k_prev, k[:-1]])
    km2 = jnp.concatenate([k_prev2, km1[:-1]])
    o_ref[...] = k - 2 * km1 + km2


def _dequantize_kernel(k_ref, x0_ref, step_ref, o_ref):
    o_ref[...] = (x0_ref[0] + k_ref[...].astype(jnp.float32) * step_ref[0]).astype(
        jnp.float32
    )


def _metrics_kernel(x_ref, y_ref, sse_ref, maxerr_ref):
    d = (x_ref[...] - y_ref[...]).astype(jnp.float32)
    sse_ref[0] = jnp.sum(d * d)
    maxerr_ref[0] = jnp.max(jnp.abs(d))


def quantize_codes(x, x0, inv_step, order, block=BLOCK):
    """Pallas quantize+difference. `x.shape[0]` must be a multiple of
    `block`; `x0`/`inv_step` are (1,)-shaped f32. Returns int32 codes.
    """
    n = x.shape[0]
    assert n % block == 0 and n > 0, f"n={n} not a multiple of block={block}"
    grid = (n // block,)
    xspec = pl.BlockSpec((block,), lambda i: (i,))
    ospec = pl.BlockSpec((block,), lambda i: (i,))
    if order == 1:
        return pl.pallas_call(
            _quantize_lv_kernel,
            grid=grid,
            in_specs=[xspec, _halo_spec(block), _scalar_spec(), _scalar_spec()],
            out_specs=ospec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
            interpret=True,
        )(x, x, x0, inv_step)
    elif order == 2:
        return pl.pallas_call(
            _quantize_lcf_kernel,
            grid=grid,
            in_specs=[
                xspec,
                _halo_spec(block),
                _halo2_spec(block),
                _scalar_spec(),
                _scalar_spec(),
            ],
            out_specs=ospec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
            interpret=True,
        )(x, x, x, x0, inv_step)
    raise ValueError(f"order must be 1 or 2, got {order}")


def dequantize_values(k, x0, step, block=BLOCK):
    """Pallas dequantization: x0 + k*step (elementwise, blocked)."""
    n = k.shape[0]
    assert n % block == 0 and n > 0
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(k, x0, step)


def metrics_partials(x, y, block=BLOCK):
    """Per-block (sse, max_abs_err) partial reductions via Pallas."""
    n = x.shape[0]
    assert n % block == 0 and n > 0
    nb = n // block
    return pl.pallas_call(
        _metrics_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=True,
    )(x, y)
