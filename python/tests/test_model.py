"""L2 model graphs: composition, padding arithmetic, and AOT lowering.

The AOT smoke test lowers every graph at a reduced size and checks the
HLO text parses structurally (entry computation present, right
parameter count) — the full-size artifacts are produced by
`make artifacts` and exercised end-to-end from Rust."""

import re

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_quantize_dequantize_roundtrip_lv():
    n = 1024
    rng = np.random.default_rng(7)
    x = np.cumsum(rng.normal(0, 0.1, n)).astype(np.float32)
    eb = 1e-3 * float(x.max() - x.min())
    step = 2.0 * eb
    x0 = jnp.asarray([x[0]], jnp.float32)
    inv = jnp.asarray([1.0 / step], jnp.float32)
    stepj = jnp.asarray([step], jnp.float32)

    codes = model.quantize_lv(jnp.asarray(x), x0, inv)
    recon = model.dequantize_lv(codes, x0, stepj)
    err = np.abs(np.asarray(recon, np.float64) - x.astype(np.float64))
    assert err.max() <= eb * 1.01 + abs(x).max() * 1e-6


def test_quantize_dequantize_roundtrip_lcf():
    n = 512
    x = (np.sin(np.arange(n) * 0.01) * 40).astype(np.float32)
    eb = 1e-3 * float(x.max() - x.min())
    step = 2.0 * eb
    x0 = jnp.asarray([x[0]], jnp.float32)
    inv = jnp.asarray([1.0 / step], jnp.float32)
    stepj = jnp.asarray([step], jnp.float32)

    codes = model.quantize_lcf(jnp.asarray(x), x0, inv)
    recon = model.dequantize_lcf(codes, x0, stepj)
    err = np.abs(np.asarray(recon, np.float64) - x.astype(np.float64))
    assert err.max() <= eb * 1.01 + abs(x).max() * 1e-6


def test_field_metrics_values():
    x = jnp.asarray(np.arange(256, dtype=np.float32))
    y = x + 0.5
    sse, maxerr = model.field_metrics(x, y)
    np.testing.assert_allclose(float(sse[0]), 256 * 0.25, rtol=1e-6)
    np.testing.assert_allclose(float(maxerr[0]), 0.5, rtol=1e-6)


def test_model_matches_ref_on_full_block():
    n = 2048
    rng = np.random.default_rng(3)
    x = rng.uniform(-5, 5, n).astype(np.float32)
    x0 = jnp.asarray([x[0]], jnp.float32)
    inv = jnp.asarray([100.0], jnp.float32)
    got = model.quantize_lv(jnp.asarray(x), x0, inv)
    want = ref.quantize_codes_ref(jnp.asarray(x), x0[0], inv[0], order=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_aot_graphs_lower_to_hlo_text():
    n = 256
    for name, (fn, specs, inputs) in aot.graphs(n).items():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, f"{name}: no entry computation"
        # Header: entry_computation_layout={(f32[256]{0}, f32[1]{0}, ...)->...}
        m = re.search(r"entry_computation_layout=\{\(([^)]*)\)->", text)
        assert m, f"{name}: cannot parse entry layout"
        n_params = len([p for p in m.group(1).split(",") if p.strip()])
        assert n_params == len(specs), (
            f"{name}: {n_params} entry parameters, expected {len(specs)}"
        )
        assert len(text) > 200


def test_manifest_inputs_match_graph_arity():
    for name, (fn, specs, inputs) in aot.graphs(256).items():
        assert len(inputs.split(",")) == len(specs), name
