"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, value scales, error bounds, and predictor
order; every case asserts bit-identical codes and bound-respecting
reconstruction. This is the CORE correctness signal for the AOT
artifacts the Rust hot path executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as kq
from compile.kernels import ref


def _scalars(x0, inv_step):
    return (
        jnp.asarray([x0], dtype=jnp.float32),
        jnp.asarray([inv_step], dtype=jnp.float32),
    )


def _field(draw_style, n, seed):
    rng = np.random.default_rng(seed)
    if draw_style == 0:  # smooth walk
        x = np.cumsum(rng.normal(0, 0.01, n)).astype(np.float32)
    elif draw_style == 1:  # white noise
        x = rng.uniform(-100, 100, n).astype(np.float32)
    elif draw_style == 2:  # piecewise with jumps
        x = np.cumsum(rng.normal(0, 0.01, n))
        jumps = rng.random(n) < 0.02
        x[jumps] += rng.uniform(-50, 50, jumps.sum())
        x = x.astype(np.float32)
    else:  # constant
        x = np.full(n, 3.25, dtype=np.float32)
    return x


@settings(max_examples=60, deadline=None)
@given(
    n_blocks=st.integers(1, 6),
    block=st.sampled_from([8, 64, 256]),
    style=st.integers(0, 3),
    order=st.sampled_from([1, 2]),
    eb_exp=st.floats(-5.0, -1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_codes_match_ref(n_blocks, block, style, order, eb_exp, seed):
    n = n_blocks * block
    x = _field(style, n, seed)
    rng = float(x.max() - x.min()) or 1.0
    eb = (10.0**eb_exp) * rng
    inv_step = 1.0 / (2.0 * eb)
    x0, inv = _scalars(x[0], inv_step)
    xj = jnp.asarray(x)

    got = kq.quantize_codes(xj, x0, inv, order=order, block=block)
    want = ref.quantize_codes_ref(xj, x0[0], inv[0], order=order)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32
    assert int(got[0]) == 0


@settings(max_examples=40, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([8, 128]),
    style=st.integers(0, 2),
    order=st.sampled_from([1, 2]),
    eb_exp=st.floats(-4.0, -1.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_respects_bound(n_blocks, block, style, order, eb_exp, seed):
    n = n_blocks * block
    x = _field(style, n, seed)
    rng = float(x.max() - x.min()) or 1.0
    eb = (10.0**eb_exp) * rng
    step = 2.0 * eb
    x0, inv = _scalars(x[0], 1.0 / step)
    stepj = jnp.asarray([step], dtype=jnp.float32)
    xj = jnp.asarray(x)

    codes = kq.quantize_codes(xj, x0, inv, order=order, block=block)
    k = ref.reconstruct_k_ref(codes, order)
    recon = kq.dequantize_values(k.astype(jnp.int32), x0, stepj, block=block)
    err = np.abs(np.asarray(recon, dtype=np.float64) - x.astype(np.float64))
    # f32 lattice math leaves a small slop; the Rust side verifies the
    # exact user bound and escapes violators (DESIGN.md par.3).
    tol = eb * (1.0 + 1e-3) + float(np.abs(x).max()) * 1e-6
    assert err.max() <= tol, f"max err {err.max():e} vs eb {eb:e}"


@settings(max_examples=30, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_metrics_match_ref(n_blocks, block, seed):
    n = n_blocks * block
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 10, n).astype(np.float32)
    y = (x + rng.normal(0, 0.1, n)).astype(np.float32)
    sse_p, max_p = kq.metrics_partials(jnp.asarray(x), jnp.asarray(y), block=block)
    sse, maxerr = float(jnp.sum(sse_p)), float(jnp.max(max_p))
    rsse, rmax = ref.metrics_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(sse, float(rsse), rtol=1e-5)
    np.testing.assert_allclose(maxerr, float(rmax), rtol=1e-6)


def test_first_code_is_zero_every_block_boundary():
    # The halo trick: block boundaries must NOT reset the prediction.
    n, block = 64, 8
    # Exactly-representable ramp: steps of 0.5 on a lattice of 0.25.
    x = (0.5 * np.arange(n)).astype(np.float32)
    x0, inv = _scalars(x[0], 1.0 / 0.25)
    codes = np.asarray(kq.quantize_codes(jnp.asarray(x), x0, inv, order=1, block=block))
    want = np.asarray(
        ref.quantize_codes_ref(jnp.asarray(x), x0[0], inv[0], order=1)
    )
    np.testing.assert_array_equal(codes, want)
    # A linear ramp has constant LV codes everywhere after index 0 —
    # including at block boundaries (indices 8, 16, ...).
    assert np.all(codes[1:] == codes[1])


def test_order2_is_zero_on_linear_ramp():
    n, block = 64, 8
    x = (3.0 + 0.5 * np.arange(n)).astype(np.float32)
    x0, inv = _scalars(x[0], 1.0 / 0.5)
    codes = np.asarray(kq.quantize_codes(jnp.asarray(x), x0, inv, order=2, block=block))
    # LCF predicts a linear ramp exactly: codes are 0 except index 1.
    assert codes[0] == 0
    assert np.all(codes[2:] == 0), codes[:10]


def test_bad_order_raises():
    x = jnp.zeros((8,), jnp.float32)
    x0, inv = _scalars(0.0, 1.0)
    with pytest.raises(ValueError):
        kq.quantize_codes(x, x0, inv, order=3, block=8)


def test_block_must_divide():
    x = jnp.zeros((10,), jnp.float32)
    x0, inv = _scalars(0.0, 1.0)
    with pytest.raises(AssertionError):
        kq.quantize_codes(x, x0, inv, order=1, block=8)
