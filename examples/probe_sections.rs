//! Dev probe: per-section compressed sizes for the CPC2000 family plus
//! compress timing of the three modes (used to calibrate Fig. 4 shape).

use nblc::compressors::{mode_compressor, registry, Mode};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::quality::Quality;
use nblc::util::stats::entropy_bits;
use nblc::util::timer::time_it;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(500_000);
    let s = generate_md(&MdConfig {
        n_particles: n,
        ..Default::default()
    });
    let eb_rel = 1e-4;
    let quality = Quality::rel(eb_rel);

    for name in ["cpc2000", "sz_cpc2000", "sz_lv", "sz_lv_prx"] {
        let c = registry::build_str(name).unwrap();
        let (bundle, secs) = time_it(|| c.compress(&s, &quality).unwrap());
        println!(
            "{name:12} ratio={:.3} rate={:.1} MB/s",
            bundle.compression_ratio(),
            (s.total_bytes() as f64 / 1e6) / secs
        );
        for f in &bundle.fields {
            println!(
                "    {:8} {:9} bytes  {:5.2} bits/val",
                f.name,
                f.bytes.len(),
                f.bytes.len() as f64 * 8.0 / f.n as f64 * if f.name == "coords" { 3.0 } else { 1.0 } / if f.name == "coords" { 3.0 } else { 1.0 }
            );
        }
    }

    // Entropy of LV-diff codes on a velocity field for reference.
    let eb = nblc::util::stats::value_range(&s.fields[3]) * eb_rel;
    let q = nblc::model::quant::LatticeQuantizer::new(eb).unwrap();
    let codes = q.quantize(&s.fields[3], nblc::model::quant::Predictor::LastValue);
    println!(
        "vx LV-code entropy = {:.2} bits",
        entropy_bits(codes.codes.iter().copied())
    );

    for mode in [Mode::BestSpeed, Mode::BestTradeoff, Mode::BestCompression] {
        let c = mode_compressor(mode);
        let (bundle, secs) = time_it(|| c.compress(&s, &quality).unwrap());
        println!(
            "{:16} ratio={:.3} rate={:.1} MB/s",
            mode.name(),
            bundle.compression_ratio(),
            (s.total_bytes() as f64 / 1e6) / secs
        );
    }
}
