//! Molecular-dynamics scenario: the three compression modes on an
//! AMDF-like nanoparticle snapshot (paper §VI / conclusion) — pick the
//! mode that matches your I/O budget.
//!
//! Run: `cargo run --release --example md_modes [n_particles]`

use nblc::compressors::{mode_compressor, Mode};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::quality::Quality;
use nblc::util::humansize;
use nblc::util::timer::time_it;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(500_000);
    let eb_rel = 1e-4;
    let quality = Quality::rel(eb_rel);
    let snap = generate_md(&MdConfig {
        n_particles: n,
        ..Default::default()
    });
    let mb = snap.total_bytes() as f64 / 1e6;
    println!(
        "AMDF-like snapshot: {} atoms, {} @ eb_rel={eb_rel:.0e}\n",
        snap.len(),
        humansize::bytes(snap.total_bytes() as u64)
    );
    println!("{:<18} {:>8} {:>12} {:>14}", "mode", "ratio", "rate", "use when");
    let advice = [
        "simulation is compute-bound; I/O is cheap",
        "balanced runs (default)",
        "storage/bandwidth is the bottleneck",
    ];
    let mut rows = Vec::new();
    for (mode, hint) in [
        Mode::BestSpeed,
        Mode::BestTradeoff,
        Mode::BestCompression,
    ]
    .into_iter()
    .zip(advice)
    {
        let comp = mode_compressor(mode);
        let (bundle, secs) = time_it(|| comp.compress(&snap, &quality).unwrap());
        rows.push((mode, bundle.compression_ratio(), mb / secs));
        println!(
            "{:<18} {:>8.2} {:>10.1} MB/s {:>14}",
            mode.name(),
            bundle.compression_ratio(),
            mb / secs,
            hint
        );
    }
    // The mode contract (paper Fig. 4).
    assert!(rows[0].2 >= rows[1].2, "best_speed must be fastest");
    assert!(
        rows[2].1 >= rows[0].1,
        "best_compression must out-compress best_speed"
    );
    assert!(
        rows[1].1 >= rows[0].1,
        "best_tradeoff must out-compress best_speed"
    );
    println!("\nmode contract holds: speed ordering and ratio ordering as documented.");
}
