//! Rate-distortion explorer: sweep error bounds for a chosen method and
//! data set and print the (bits/value, PSNR) curve — the Fig. 6 tooling
//! exposed as a user-facing utility.
//!
//! Run: `cargo run --release --example rate_distortion [spec] [hacc|amdf]`
//! where `spec` is a registry codec spec, e.g. `sz_lv` or
//! `sz_lv_rx:segment=4096`.

use nblc::compressors::registry;
use nblc::data::DatasetKind;
use nblc::metrics::ratedist::{rate_distortion_curve, standard_bounds};
use nblc::snapshot::Snapshot;

fn main() {
    let method = std::env::args().nth(1).unwrap_or_else(|| "sz_lv".into());
    let dataset = std::env::args().nth(2).unwrap_or_else(|| "hacc".into());
    let kind = match dataset.as_str() {
        "amdf" => DatasetKind::Amdf,
        _ => DatasetKind::Hacc,
    };
    let comp = registry::build_str(&method).unwrap_or_else(|e| {
        eprintln!("bad method spec '{method}': {e}");
        std::process::exit(2);
    });
    let n = 300_000.min(nblc::data::default_n(kind));
    let snap = nblc::data::generate(kind, n, nblc::bench::BENCH_SEED);

    // Reordering methods need the aligned reference for PSNR; the
    // registry rebuilds the sort permutation with the spec's own
    // tuning parameters.
    let perm_spec = method.clone();
    let perm_fn: Option<Box<dyn Fn(&Snapshot, f64) -> nblc::Result<Vec<u32>>>> =
        if comp.reorders() {
            Some(Box::new(move |s: &Snapshot, eb: f64| {
                Ok(registry::sort_permutation(&perm_spec, s, eb)?
                    .expect("reordering codec has a sort permutation"))
            }))
        } else {
            None
        };

    println!("rate-distortion: {method} on {} (n={n})\n", kind.name());
    println!("{:>10} {:>12} {:>10} {:>8}", "eb_rel", "bits/value", "PSNR(dB)", "ratio");
    let points = rate_distortion_curve(
        &snap,
        comp.as_ref(),
        &standard_bounds(),
        perm_fn.as_ref().map(|f| f.as_ref() as _),
    );
    for p in &points {
        println!(
            "{:>10.0e} {:>12.2} {:>10.1} {:>8.2}",
            p.eb_rel, p.bit_rate, p.psnr, p.ratio
        );
    }
    assert!(!points.is_empty(), "no achievable bounds for {method}");
    // Monotonicity sanity: tighter bounds give higher PSNR.
    for w in points.windows(2) {
        assert!(
            w[1].psnr >= w[0].psnr - 1e-6,
            "PSNR must rise as the bound tightens"
        );
    }
}
