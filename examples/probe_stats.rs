//! Dev probe: print per-field prediction NRMSE (Table III analogue) and
//! orderliness stats for both generators. Used to calibrate the
//! generators against the paper's statistics.

use nblc::data::gen_cosmo::{generate_cosmo, CosmoConfig};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::snapshot::FIELD_NAMES;
use nblc::util::stats::{autocorrelation, monotone_fraction, value_range};

fn report(name: &str, snap: &nblc::snapshot::Snapshot) {
    println!("== {name} (n={}) ==", snap.len());
    println!("{:>4} {:>12} {:>12} {:>10} {:>10} {:>10}", "fld", "NRMSE(LCF)", "NRMSE(LV)", "range", "mono", "ac1");
    for f in 0..6 {
        let lcf = LatticeQuantizer::prediction_nrmse(&snap.fields[f], Predictor::LinearCurveFit);
        let lv = LatticeQuantizer::prediction_nrmse(&snap.fields[f], Predictor::LastValue);
        println!(
            "{:>4} {:>12.5} {:>12.5} {:>10.2} {:>10.3} {:>10.3}",
            FIELD_NAMES[f],
            lcf,
            lv,
            value_range(&snap.fields[f]),
            monotone_fraction(&snap.fields[f]),
            autocorrelation(&snap.fields[f], 1),
        );
    }
}

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let cosmo = generate_cosmo(&CosmoConfig { n_particles: n, ..Default::default() });
    report("HACC-like", &cosmo);
    let md = generate_md(&MdConfig { n_particles: n, ..Default::default() });
    report("AMDF-like", &md);
}
