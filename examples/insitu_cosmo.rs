//! END-TO-END DRIVER: the full system on a real (synthetic-cosmology)
//! workload — the paper's headline use case.
//!
//! Layers exercised:
//!   L1     the kernel backend (scalar or SIMD, runtime-detected) on
//!          the quantize / entropy / key-build hot loops;
//!   L3     scheduler routing (par.V-C), sharded in-situ pipeline with
//!          bounded-queue backpressure, GPFS-model sink;
//!   +      decompression + per-element bound verification, and the
//!          paper's headline metric (I/O-time reduction vs direct write
//!          at 1024 simulated processes).
//!
//! Run: `cargo run --release --example insitu_cosmo [n_particles]`
//! Results recorded in EXPERIMENTS.md par.End-to-end.

use nblc::compressors::sz::Sz;
use nblc::compressors::{registry, Mode};
use nblc::coordinator::pipeline::{run_insitu, InsituConfig, Sink};
use nblc::coordinator::{choose_compressor, GpfsModel};
use nblc::data::gen_cosmo::{generate_cosmo, CosmoConfig};
use nblc::quality::Quality;
use nblc::snapshot::{verify_bounds, PerField, SnapshotCompressor};
use nblc::util::humansize;
use nblc::util::timer::Timer;

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let eb_rel = 1e-4;
    let quality = Quality::rel(eb_rel);

    println!("=== nblc end-to-end in-situ driver (HACC-like, n={n}) ===\n");
    let t = Timer::start();
    let snap = generate_cosmo(&CosmoConfig {
        n_particles: n,
        ..Default::default()
    });
    println!(
        "[1/5] generated snapshot: {} in {}",
        humansize::bytes(snap.total_bytes() as u64),
        humansize::secs(t.secs())
    );

    // Scheduler: cosmology data has an orderly coordinate -> SZ-LV.
    let mode = choose_compressor(&snap, Mode::BestCompression);
    println!(
        "[2/5] scheduler routed best_compression -> {} (orderly yy detected: {})",
        mode.name(),
        mode == Mode::BestSpeed
    );

    println!(
        "[3/5] kernel backend: {} (NBLC_SIMD={} resolves here; bytes are backend-invariant)",
        nblc::kernels::active().label,
        nblc::kernels::mode().name(),
    );
    let factory = registry::factory(&Mode::BestSpeed.spec()).expect("mode spec is registry-valid");

    let shards = (n / (1 << 18)).max(1);
    let sim_procs = 1024;
    let report = run_insitu(
        &snap,
        &InsituConfig {
            shards,
            layout: None,
            workers: 1,
            threads: 1,
            queue_depth: 4,
            quality: quality.clone(),
            factory,
            sink: Sink::Model {
                model: GpfsModel::default(),
                procs: sim_procs,
            },
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .expect("pipeline failed");
    println!(
        "      pipeline: ratio {:.2}, compress rate {}, wall {}, stalls src={} ",
        report.ratio,
        humansize::rate(report.compress_rate),
        humansize::secs(report.wall_secs),
        report.source_stalls,
    );

    // Verify: recompress + decompress one pass over the whole snapshot
    // through the same streams; also measures the single-core rate used
    // for the cluster projection.
    let comp = PerField(Sz::lv());
    let t_native = Timer::start();
    let bundle = comp.compress(&snap, &quality).expect("compress");
    let native_rate = snap.total_bytes() as f64 / t_native.secs();
    let recon = comp.decompress(&bundle).expect("decompress");
    verify_bounds(&snap, &recon, eb_rel).expect("bound verification");
    println!(
        "[4/5] verified: every one of {} values within eb_rel={eb_rel:.0e} of the original",
        6 * snap.len()
    );

    // Headline metric: projected I/O time at 1024 processes.
    let model = GpfsModel::default();
    let single_core_rate = native_rate;
    let (t0, tc, twc) = model.insitu_times(1 << 30, sim_procs, single_core_rate, report.ratio);
    let reduction = 1.0 - (tc + twc) / t0;
    println!(
        "[5/5] headline @ {sim_procs} procs (GPFS model, measured rate {}):",
        humansize::rate(single_core_rate)
    );
    println!("      write initial data : {t0:>8.1} s");
    println!("      compress           : {tc:>8.1} s");
    println!("      write compressed   : {twc:>8.1} s");
    println!(
        "      => I/O time reduction {:.1}% (paper: ~80%)",
        reduction * 100.0
    );
    assert!(reduction > 0.6, "end-to-end driver must reproduce the headline");
    println!("\nOK — all layers composed.");
}
