//! Quickstart: generate a small snapshot, compress it with every
//! method (built from its codec spec via the registry), decompress,
//! and verify the error bound.
//!
//! Run: `cargo run --release --example quickstart`

use nblc::compressors::{full_lineup, registry};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::quality::Quality;
use nblc::snapshot::verify_bounds;
use nblc::util::timer::time_it;

fn main() {
    let eb_rel = 1e-4;
    let quality = Quality::rel(eb_rel);
    let snap = generate_md(&MdConfig {
        n_particles: 200_000,
        ..Default::default()
    });
    println!(
        "snapshot: {} particles, {} bytes, eb_rel = {eb_rel:.0e}\n",
        snap.len(),
        snap.total_bytes()
    );
    println!(
        "{:<12} {:>8} {:>12} {:>12}  {}",
        "method", "ratio", "compress", "decompress", "verified"
    );
    for name in full_lineup() {
        let comp = registry::build_str(name).unwrap();
        let (bundle, t_c) = time_it(|| comp.compress(&snap, &quality).unwrap());
        let (recon, t_d) = time_it(|| comp.decompress(&bundle).unwrap());
        // Reordering methods return a consistent permutation of the
        // particles; align with the deterministic sort to verify.
        let reference = match registry::sort_permutation(name, &snap, eb_rel).unwrap() {
            Some(perm) => snap.permute(&perm).unwrap(),
            None => snap.clone(),
        };
        let verified = if name == "fpzip" {
            // FPZIP is precision-based: near the bound, not strictly under.
            "~ (precision mode)".to_string()
        } else {
            verify_bounds(&reference, &recon, eb_rel).map(|_| "yes").unwrap().to_string()
        };
        println!(
            "{name:<12} {:>8.2} {:>10.1}ms {:>10.1}ms  {verified}",
            bundle.compression_ratio(),
            t_c * 1e3,
            t_d * 1e3,
        );
    }
    println!("\nall methods round-tripped within the error bound.");
}
