#!/usr/bin/env python3
"""Hot-path bench regression gate.

Compares the freshly generated ``results/BENCH_hotpath.json`` against the
checked-in ``results/BENCH_baseline.json`` and fails when any gated row's
throughput drops more than the tolerance below its baseline. Rows are
matched by ``(codec, threads)``; only rows present in the baseline are
gated, so adding new bench rows never breaks the gate.

Environment:
  NBLC_BENCH_GATE=off|0|skip   skip entirely (cold/shared runners)
  NBLC_BENCH_TOLERANCE=0.2     allowed fractional drop (default 20%)

Exit status: 0 pass/skipped, 1 regression, 2 usage/parse error.
"""

import json
import os
import sys


def load_rows(path):
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    out = {}
    for row in rows:
        try:
            out[(row["codec"], int(row["threads"]))] = float(row["mb_per_s"])
        except (KeyError, TypeError, ValueError) as e:
            print(f"error: malformed row {row!r} in {path}: {e}", file=sys.stderr)
            sys.exit(2)
    return out


def main():
    if os.environ.get("NBLC_BENCH_GATE", "").lower() in ("off", "0", "skip"):
        print("bench gate: skipped (NBLC_BENCH_GATE set)")
        return 0
    if len(sys.argv) != 3:
        print(f"usage: {sys.argv[0]} <measured.json> <baseline.json>", file=sys.stderr)
        return 2
    measured = load_rows(sys.argv[1])
    baseline = load_rows(sys.argv[2])
    try:
        tolerance = float(os.environ.get("NBLC_BENCH_TOLERANCE", "0.2"))
    except ValueError:
        print("error: NBLC_BENCH_TOLERANCE is not a number", file=sys.stderr)
        return 2

    failures = []
    for key, base in sorted(baseline.items()):
        codec, threads = key
        floor = base * (1.0 - tolerance)
        got = measured.get(key)
        if got is None:
            failures.append(f"{codec}@{threads}t: row missing from measured results")
            continue
        verdict = "OK" if got >= floor else "REGRESSION"
        print(
            f"bench gate: {codec}@{threads}t {got:8.2f} MB/s"
            f"  (baseline {base:.2f}, floor {floor:.2f})  {verdict}"
        )
        if got < floor:
            failures.append(
                f"{codec}@{threads}t: {got:.2f} MB/s is more than "
                f"{tolerance:.0%} below baseline {base:.2f} MB/s"
            )
    if failures:
        for f in failures:
            print(f"bench gate FAILED: {f}", file=sys.stderr)
        print(
            "Re-baseline results/BENCH_baseline.json if this drop is intended, "
            "or set NBLC_BENCH_GATE=off on cold runners.",
            file=sys.stderr,
        )
        return 1
    print("bench gate: all gated rows within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
