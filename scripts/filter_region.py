#!/usr/bin/env python3
"""Brute-force region filter over a .snap snapshot file.

Keeps exactly the particles whose (x, y, z) lie inside an axis-aligned,
half-open box — the same membership rule as `Region::contains` in
rust/src/data/archive.rs — preserving particle order and the snapshot
header. CI uses this as the independent reference for
`nblc decompress --region`: filtering the FULL decode with this script
must reproduce the pruned region decode byte-for-byte.

Usage: filter_region.py in.snap x0 x1 y0 y1 z0 z1 out.snap

The box corners must be exactly f32-representable (CI uses small
integers), so comparing the widened-to-f64 field values against them
matches the f32 comparison the decoder performs.
"""

import struct
import sys

MAGIC = b"NBLCSNAP"
N_FIELDS = 6  # xx yy zz vx vy vz


def main() -> None:
    if len(sys.argv) != 9:
        sys.exit("usage: filter_region.py in.snap x0 x1 y0 y1 z0 z1 out.snap")
    src, out = sys.argv[1], sys.argv[8]
    lo = [float(v) for v in sys.argv[2:8:2]]
    hi = [float(v) for v in sys.argv[3:8:2]]

    with open(src, "rb") as f:
        blob = f.read()
    if blob[:8] != MAGIC:
        sys.exit(f"{src}: bad magic {blob[:8]!r}")
    version = struct.unpack_from("<I", blob, 8)[0]
    if version != 1:
        sys.exit(f"{src}: unsupported snapshot version {version}")
    n = struct.unpack_from("<Q", blob, 12)[0]
    name_len = struct.unpack_from("<I", blob, 36)[0]
    base = 40 + name_len
    if len(blob) != base + 4 * n * N_FIELDS:
        sys.exit(f"{src}: truncated (n={n}, {len(blob)} bytes)")
    fields = [
        struct.unpack_from(f"<{n}f", blob, base + 4 * n * i) for i in range(N_FIELDS)
    ]

    # Half-open on every axis: lo <= p < hi (Region::contains).
    keep = [
        i for i in range(n) if all(lo[a] <= fields[a][i] < hi[a] for a in range(3))
    ]

    with open(out, "wb") as f:
        f.write(blob[:12])
        f.write(struct.pack("<Q", len(keep)))
        f.write(blob[20:base])  # box_size, seed, name — copied verbatim
        for plane in fields:
            f.write(struct.pack(f"<{len(keep)}f", *(plane[i] for i in keep)))
    print(f"kept {len(keep)}/{n} particles")


if __name__ == "__main__":
    main()
