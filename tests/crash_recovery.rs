//! Crash-recovery acceptance sweep: tear an archive-sink pipeline run
//! at EVERY write index across the FULL codec lineup × {cost, spatial}
//! layouts, then prove three things about each torn file:
//!
//!   1. the fault surfaces as a *typed* degradation (a populated
//!      [`InsituReport::failures`] table — never a panic, never `Err`
//!      from `run_insitu` itself);
//!   2. `ShardReader::open_salvage` recovers exactly the CRC-valid
//!      contiguous record prefix — the salvage boundary lands on the
//!      byte where the fault-free run put the next record;
//!   3. every recovered shard is byte-identical to (and decodes
//!      bitwise-equal with) the same shard of an uninterrupted run.
//!
//! A second test pins the self-healing side: a pipeline with
//! `max_retries ≥ 1` that rides out transient compressor faults writes
//! a file byte-identical to the fault-free run, on both layouts.

use nblc::compressors::{full_lineup, registry};
use nblc::coordinator::pipeline::{
    run_insitu, CompressorFactory, InsituConfig, Sink, SpatialInsitu,
};
use nblc::coordinator::spatial::plan_spatial;
use nblc::data::archive::{ShardEntry, ShardReader};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::error::{Error, Result};
use nblc::exec::ExecCtx;
use nblc::quality::Quality;
use nblc::snapshot::{CompressedSnapshot, Snapshot, SnapshotCompressor};
use nblc::testkit::{FaultKind, FaultPlan};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

const N: usize = 2_400;
const EB: f64 = 1e-4;
const SHARDS: usize = 3;
/// Sweep guard: far above any real per-run write-op count (~60 for
/// three six-field shards) so a runaway loop fails loudly instead of
/// spinning.
const MAX_WRITE_OPS: u64 = 300;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nblc_crash_{tag}_{}.nblc", std::process::id()))
}

/// The deterministic region of a v3 file: header + shard records (what
/// `file_crc` pins). The footer carries wall-clock `cost_ns` counters,
/// so whole-file comparisons would flake.
fn data_region(bytes: &[u8]) -> &[u8] {
    let foot_len =
        u64::from_le_bytes(bytes[bytes.len() - 16..bytes.len() - 8].try_into().unwrap());
    &bytes[..bytes.len() - 16 - foot_len as usize]
}

fn cfg(
    path: &Path,
    spec: &str,
    factory: CompressorFactory,
    layout: Option<Vec<nblc::coordinator::shard::Shard>>,
    spatial: Option<SpatialInsitu>,
    max_retries: usize,
    sink_fault: Option<FaultPlan>,
) -> InsituConfig {
    InsituConfig {
        shards: SHARDS,
        layout,
        // Single worker: completion order == task order, so the torn
        // file's record prefix is comparable shard-for-shard against
        // the fault-free file.
        workers: 1,
        threads: 1,
        queue_depth: 2,
        quality: Quality::rel(EB),
        factory,
        sink: Sink::Archive {
            path: path.to_path_buf(),
            spec: spec.into(),
        },
        spatial,
        max_retries,
        sink_fault,
    }
}

fn entry_key(e: &ShardEntry) -> (u64, u64, u64, u64, u64) {
    (e.start, e.end, e.offset, e.len, e.bytes_out)
}

/// Decode a shard bundle and return the bit patterns of every field —
/// "bitwise-equal" means exactly this, with no float comparison slack.
fn decoded_bits(codec: &dyn SnapshotCompressor, bundle: &CompressedSnapshot) -> Vec<Vec<u32>> {
    let dec = codec
        .decompress_with(&ExecCtx::sequential(), bundle)
        .expect("recovered shard must decode");
    dec.fields
        .iter()
        .map(|f| f.iter().map(|x| x.to_bits()).collect())
        .collect()
}

/// One (codec, layout) cell of the sweep: baseline run, then a fault at
/// every write index until the plan stops tripping.
fn sweep_codec_layout(
    snap: &Snapshot,
    spec: &str,
    layout: Option<Vec<nblc::coordinator::shard::Shard>>,
    spatial: Option<SpatialInsitu>,
    tag: &str,
) {
    let factory = registry::factory(spec).unwrap();
    let codec = factory();

    // Fault-free baseline for this cell.
    let base_path = tmp(&format!("{tag}_base"));
    let report = run_insitu(
        snap,
        &cfg(
            &base_path,
            spec,
            Arc::clone(&factory),
            layout.clone(),
            spatial.clone(),
            0,
            None,
        ),
    )
    .unwrap_or_else(|e| panic!("{tag}: baseline pipeline failed: {e}"));
    assert!(report.failures.is_empty(), "{tag}: {:?}", report.failures);
    let base_reader = ShardReader::open(&base_path).unwrap();
    let base_entries: Vec<ShardEntry> = base_reader.index().entries.clone();
    assert_eq!(base_entries.len(), SHARDS, "{tag}");
    let base_bytes = std::fs::read(&base_path).unwrap();
    let base_data_len = data_region(&base_bytes).len() as u64;

    let mut last_recovered: Option<usize> = None;
    let mut completed_at = None;
    for at in 0..MAX_WRITE_OPS {
        // Cycle the fault flavors so every index is hit by one of them
        // and every flavor covers a third of the indices.
        let kind = match at % 3 {
            0 => FaultKind::Enospc,
            1 => FaultKind::Short,
            _ => FaultKind::Eio,
        };
        let path = tmp(&format!("{tag}_at{at}"));
        let report = run_insitu(
            snap,
            &cfg(
                &path,
                spec,
                Arc::clone(&factory),
                layout.clone(),
                spatial.clone(),
                0,
                Some(FaultPlan::new(at, kind)),
            ),
        )
        .unwrap_or_else(|e| panic!("{tag}@{at}: run_insitu must degrade, not abort: {e}"));

        if report.failures.is_empty() {
            // The plan outlived the file: every write succeeded, so we
            // have seen every fault index this cell can produce.
            assert!(report.shard_index.is_some(), "{tag}@{at}");
            let bytes = std::fs::read(&path).unwrap();
            assert_eq!(
                data_region(&bytes),
                data_region(&base_bytes),
                "{tag}@{at}: untripped run must match the baseline"
            );
            std::fs::remove_file(&path).ok();
            completed_at = Some(at);
            break;
        }

        // 1. Typed degradation: a failure table, no completed index.
        assert!(report.shard_index.is_none(), "{tag}@{at}");
        for f in &report.failures {
            assert!(
                f.stage == "write" || f.stage == "archive",
                "{tag}@{at}: sink faults must surface at the sink: {f:?}"
            );
        }

        // 2 + 3. Salvage the torn file and compare against baseline.
        match ShardReader::open_salvage(&path) {
            Ok((reader, rep)) => {
                assert!(!rep.had_footer, "{tag}@{at}: a torn file has no footer");
                let k = rep.shards_recovered;
                assert!((1..=SHARDS).contains(&k), "{tag}@{at}: {k} shards");
                assert_eq!(rep.shards_dropped, 0, "{tag}@{at}: single worker, no gaps");
                // The salvage boundary is exactly where the fault-free
                // run starts the first un-recovered record (or the
                // footer, when every record survived).
                let expected_end = if k < SHARDS {
                    base_entries[k].offset
                } else {
                    base_data_len
                };
                assert_eq!(rep.data_end, expected_end, "{tag}@{at}: salvage boundary");
                assert_eq!(rep.particles_recovered, base_entries[k - 1].end, "{tag}@{at}");
                reader
                    .verify_file_crc()
                    .unwrap_or_else(|e| panic!("{tag}@{at}: salvage CRC: {e}"));
                for i in 0..k {
                    assert_eq!(
                        entry_key(&reader.index().entries[i]),
                        entry_key(&base_entries[i]),
                        "{tag}@{at}: salvaged entry {i}"
                    );
                    let got = reader.read_shard(i).unwrap();
                    let want = base_reader.read_shard(i).unwrap();
                    assert_eq!(got.fields.len(), want.fields.len(), "{tag}@{at}/{i}");
                    for (g, w) in got.fields.iter().zip(&want.fields) {
                        assert_eq!(g.name, w.name, "{tag}@{at}/{i}");
                        assert!(g.bytes == w.bytes, "{tag}@{at}/{i}: field {}", g.name);
                    }
                    // Bitwise decode equality, checked once per distinct
                    // recovery depth (the payloads were just proven
                    // byte-identical, so deeper repeats add nothing).
                    if last_recovered != Some(k) {
                        assert!(
                            decoded_bits(codec.as_ref(), &got)
                                == decoded_bits(codec.as_ref(), &want),
                            "{tag}@{at}/{i}: decoded bits diverge"
                        );
                    }
                }
                last_recovered = Some(k);
            }
            // Early tears (inside the header or the first record) leave
            // nothing salvageable — that must still be a *typed* error.
            Err(Error::Io(e)) => panic!("{tag}@{at}: salvage hit raw I/O: {e}"),
            Err(_) => {}
        }
        std::fs::remove_file(&path).ok();
    }
    let total_ops = completed_at
        .unwrap_or_else(|| panic!("{tag}: no fault-free run within {MAX_WRITE_OPS} write ops"));
    assert!(
        total_ops > 10,
        "{tag}: only {total_ops} write ops — the failpoint cannot be threaded through the sink"
    );
    assert_eq!(
        last_recovered,
        Some(SHARDS),
        "{tag}: late faults (in the footer) must leave every shard recoverable"
    );
    std::fs::remove_file(&base_path).ok();
}

#[test]
fn crash_sweep_full_lineup_salvages_exact_prefix() {
    let snap = generate_md(&MdConfig {
        n_particles: N,
        ..Default::default()
    });
    let plan = plan_spatial(&snap, SHARDS, 8, &ExecCtx::sequential()).unwrap();
    for name in full_lineup() {
        let spec = registry::canonical(name).unwrap();
        sweep_codec_layout(&snap, &spec, None, None, &format!("{name}_cost"));
        sweep_codec_layout(
            &plan.snapshot,
            &spec,
            Some(plan.layout.clone()),
            Some(SpatialInsitu {
                bits: plan.bits,
                seg: 0,
                keys: Arc::clone(&plan.keys),
            }),
            &format!("{name}_spatial"),
        );
    }
}

/// A compressor whose first `fail_first` compress calls return a typed
/// transient error before the real codec takes over — the shape of an
/// allocator hiccup or a wedged accelerator queue.
struct Flaky {
    inner: Box<dyn SnapshotCompressor>,
    calls: Arc<AtomicUsize>,
    fail_first: usize,
}

impl SnapshotCompressor for Flaky {
    fn name(&self) -> &'static str {
        "flaky"
    }
    fn compress_with(
        &self,
        ctx: &ExecCtx,
        snap: &Snapshot,
        quality: &Quality,
    ) -> Result<CompressedSnapshot> {
        if self.calls.fetch_add(1, Ordering::SeqCst) < self.fail_first {
            return Err(Error::Pipeline("transient compressor fault".into()));
        }
        self.inner.compress_with(ctx, snap, quality)
    }
    fn decompress_with(&self, ctx: &ExecCtx, c: &CompressedSnapshot) -> Result<Snapshot> {
        self.inner.decompress_with(ctx, c)
    }
}

fn flaky_factory(spec: &str, fail_first: usize) -> CompressorFactory {
    let inner = registry::factory(spec).unwrap();
    let calls = Arc::new(AtomicUsize::new(0));
    Arc::new(move || {
        Box::new(Flaky {
            inner: inner(),
            calls: Arc::clone(&calls),
            fail_first,
        }) as Box<dyn SnapshotCompressor>
    })
}

#[test]
fn retry_enabled_pipelines_are_byte_identical_to_fault_free() {
    let snap = generate_md(&MdConfig {
        n_particles: N,
        ..Default::default()
    });
    let spec = registry::canonical("sz_lv").unwrap();
    let plan = plan_spatial(&snap, SHARDS, 8, &ExecCtx::sequential()).unwrap();
    let spatial = SpatialInsitu {
        bits: plan.bits,
        seg: 0,
        keys: Arc::clone(&plan.keys),
    };

    for (layout_name, snap, layout, spatial) in [
        ("cost", &snap, None, None),
        ("spatial", &plan.snapshot, Some(plan.layout.clone()), Some(spatial)),
    ] {
        let good = tmp(&format!("retry_good_{layout_name}"));
        let base = run_insitu(
            snap,
            &cfg(
                &good,
                &spec,
                registry::factory(&spec).unwrap(),
                layout.clone(),
                spatial.clone(),
                0,
                None,
            ),
        )
        .unwrap();
        assert_eq!(base.retries, 0, "{layout_name}");
        assert!(base.failures.is_empty(), "{layout_name}");

        // Two transient faults, budget of two retries: the first shard
        // needs both, then the codec behaves.
        let healed = tmp(&format!("retry_healed_{layout_name}"));
        let report = run_insitu(
            snap,
            &cfg(
                &healed,
                &spec,
                flaky_factory(&spec, 2),
                layout.clone(),
                spatial.clone(),
                2,
                None,
            ),
        )
        .unwrap();
        assert_eq!(report.retries, 2, "{layout_name}");
        assert!(report.failures.is_empty(), "{layout_name}: {:?}", report.failures);

        let a = std::fs::read(&good).unwrap();
        let b = std::fs::read(&healed).unwrap();
        assert_eq!(
            data_region(&a),
            data_region(&b),
            "{layout_name}: recovered run must be byte-identical"
        );
        let (gi, hi) =
            (base.shard_index.as_ref().unwrap(), report.shard_index.as_ref().unwrap());
        assert_eq!(gi.file_crc, hi.file_crc, "{layout_name}");
        for (x, y) in gi.entries.iter().zip(&hi.entries) {
            assert_eq!(entry_key(x), entry_key(y), "{layout_name}");
        }
        std::fs::remove_file(&good).ok();
        std::fs::remove_file(&healed).ok();
    }
}
