//! Round-trip property test over the whole codec lineup × error bounds
//! × data sets: compress → archive-write → archive-read → rebuild the
//! codec *from the archived spec* → decompress → verify the error bound
//! (modulo the reordering codecs' deterministic permutation).

use nblc::compressors::{full_lineup, registry};
use nblc::data::archive;
use nblc::quality::Quality;
use nblc::data::gen_cosmo::{generate_cosmo, CosmoConfig};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::snapshot::verify_bounds;

#[test]
fn full_lineup_roundtrips_through_archive() {
    let md = generate_md(&MdConfig {
        n_particles: 3000,
        ..Default::default()
    });
    let cosmo = generate_cosmo(&CosmoConfig {
        n_particles: 3000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    for (tag, snap) in [("md", &md), ("cosmo", &cosmo)] {
        for name in full_lineup() {
            for (ei, eb_rel) in [1e-3, 1e-4, 1e-5].into_iter().enumerate() {
                let ctx = format!("{tag}/{name}/eb={eb_rel:e}");
                let comp = registry::build_str(name).unwrap();
                let bundle = comp
                    .compress(snap, &Quality::rel(eb_rel))
                    .unwrap_or_else(|e| panic!("{ctx}: compress failed: {e}"));
                let spec = registry::canonical(name).unwrap();
                let path = dir.join(format!(
                    "nblc_rt_{}_{tag}_{name}_{ei}.nblc",
                    std::process::id()
                ));
                archive::write(&path, &bundle, &spec)
                    .unwrap_or_else(|e| panic!("{ctx}: write failed: {e}"));
                let arch = archive::read(&path)
                    .unwrap_or_else(|e| panic!("{ctx}: read failed: {e}"));
                std::fs::remove_file(&path).ok();
                assert_eq!(arch.version, archive::FORMAT_VERSION, "{ctx}");
                assert_eq!(arch.bundle.n, snap.len(), "{ctx}");
                assert_eq!(arch.bundle.eb_rel, eb_rel, "{ctx}");

                // Decompress with a codec rebuilt purely from the file's
                // self-description, as `nblc decompress` (no --method) does.
                let decomp = registry::build_str(&arch.spec)
                    .unwrap_or_else(|e| panic!("{ctx}: archived spec invalid: {e}"));
                let recon = decomp
                    .decompress(&arch.bundle)
                    .unwrap_or_else(|e| panic!("{ctx}: decompress failed: {e}"));
                assert_eq!(recon.len(), snap.len(), "{ctx}");

                if name == "fpzip" {
                    // Precision-based: lands *near* the requested bound,
                    // not strictly under it (paper §IV) — length check only.
                    continue;
                }
                let reference = match registry::sort_permutation(name, snap, eb_rel).unwrap() {
                    Some(perm) => snap.permute(&perm).unwrap(),
                    None => snap.clone(),
                };
                verify_bounds(&reference, &recon, eb_rel)
                    .unwrap_or_else(|e| panic!("{ctx}: bound violated: {e}"));
            }
        }
    }
}

#[test]
fn tuned_spec_roundtrips_from_archive_alone() {
    // The acceptance-criteria flow: compress with a non-default
    // parameter, then decompress knowing nothing but the archive.
    let snap = generate_md(&MdConfig {
        n_particles: 5000,
        ..Default::default()
    });
    let user_spec = "sz_lv_rx:segment=4096";
    let canonical = registry::canonical(user_spec).unwrap();
    let comp = registry::build_str(user_spec).unwrap();
    let bundle = comp.compress(&snap, &Quality::rel(1e-4)).unwrap();
    let path = std::env::temp_dir().join(format!("nblc_rt_tuned_{}.nblc", std::process::id()));
    archive::write(&path, &bundle, &canonical).unwrap();

    let arch = archive::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(arch.spec, "sz_lv_rx:ignore=0,segment=4096,source=coords");
    let recon = registry::build_str(&arch.spec)
        .unwrap()
        .decompress(&arch.bundle)
        .unwrap();
    // Align using the *archived* spec: the permutation must come out
    // with segment=4096, not the default.
    let perm = registry::sort_permutation(&arch.spec, &snap, 1e-4)
        .unwrap()
        .expect("sz_lv_rx reorders");
    let reference = snap.permute(&perm).unwrap();
    verify_bounds(&reference, &recon, 1e-4).unwrap();
}
