//! Acceptance tests for spatially-pruned region reads: every codec in
//! the lineup, spatial and cost layouts, interior / face-clipping /
//! empty / full-domain query boxes. A region decode must return exactly
//! the particles a brute-force filter of the full decode keeps (bitwise,
//! in the same order), touch no more shards than the footer bbox index
//! overlaps, and on a ≥16-shard spatial archive a small interior box
//! must decode ≤2 shards — through the library path and through a live
//! serve daemon (whose LRU cache and pruning counters are also checked).

use nblc::compressors::{full_lineup, registry};
use nblc::coordinator::spatial::{plan_spatial, shard_spatial};
use nblc::data::archive::{
    decode_region, decode_shards, Region, ShardIndex, ShardReader, ShardWriter,
};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::exec::ExecCtx;
use nblc::quality::Quality;
use nblc::serve::{GetReply, ServeClient, ServeConfig, Server};
use nblc::snapshot::Snapshot;
use std::path::Path;
use std::time::Duration;

const EB: f64 = 1e-4;
const BITS: u32 = 10;

/// Write a spatial-layout archive the way the pipeline sink does:
/// Morton-sort, cut on octree cells, per-shard footer entries computed
/// from the decoded (round-tripped) coordinates. Returns the sorted
/// snapshot (the archive's logical order) and the written index.
fn build_spatial_archive(
    path: &Path,
    snap: &Snapshot,
    spec: &str,
    shards: usize,
    seg: u64,
) -> (Snapshot, ShardIndex) {
    let quality = Quality::rel(EB);
    let comp = registry::build_str(spec).unwrap();
    let plan = plan_spatial(snap, shards, BITS, &ExecCtx::sequential()).unwrap();
    let mut w = ShardWriter::create_quality(path, spec, &quality).unwrap();
    w.enable_spatial(plan.bits, seg).unwrap();
    for sh in &plan.layout {
        let bundle = comp
            .compress(&plan.snapshot.slice(sh.start, sh.end), &quality)
            .unwrap();
        let decoded = comp.decompress(&bundle).unwrap();
        let (lo, hi) = plan.key_range(sh.start, sh.end);
        let sp = shard_spatial(&decoded, lo, hi, seg as usize);
        w.write_shard_spatial(sh.start, sh.end, &bundle, 2_000_000, sp)
            .unwrap();
    }
    let index = w.finish().unwrap();
    (plan.snapshot, index)
}

/// Cost-layout (even split) archive over the same snapshot: no spatial
/// block, so region queries must fall back to a full scan.
fn build_cost_archive(path: &Path, snap: &Snapshot, spec: &str, shards: usize) {
    let quality = Quality::rel(EB);
    let comp = registry::build_str(spec).unwrap();
    let mut w = ShardWriter::create_quality(path, spec, &quality).unwrap();
    let n = snap.len();
    for s in 0..shards {
        let (start, end) = (s * n / shards, (s + 1) * n / shards);
        let bundle = comp.compress(&snap.slice(start, end), &quality).unwrap();
        w.write_shard(start, end, &bundle, 2_000_000).unwrap();
    }
    w.finish().unwrap();
}

fn bits_of(s: &Snapshot) -> Vec<Vec<u32>> {
    s.fields
        .iter()
        .map(|f| f.iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Indices a brute-force filter of the full decode keeps.
fn brute(full: &Snapshot, r: &Region) -> Vec<usize> {
    (0..full.len())
        .filter(|&i| r.contains(full.fields[0][i], full.fields[1][i], full.fields[2][i]))
        .collect()
}

/// Assert the region decode equals the brute-force reference bitwise,
/// returning `(shards_touched, shards_pruned, indexed)`.
fn check_region(
    reader: &ShardReader,
    full: &Snapshot,
    r: &Region,
    ctx: &ExecCtx,
    what: &str,
) -> (usize, usize, bool) {
    let dec = decode_region(reader, reader.spec(), r, ctx).unwrap();
    let keep = brute(full, r);
    assert_eq!(dec.snapshot.len(), keep.len(), "{what}: membership count");
    for f in 0..6 {
        let want: Vec<u32> = keep.iter().map(|&i| full.fields[f][i].to_bits()).collect();
        let got: Vec<u32> = dec.snapshot.fields[f].iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "{what}: field {f} differs from brute force");
    }
    (dec.shards_touched, dec.shards_pruned, dec.indexed)
}

/// The four query-box shapes of the acceptance matrix, derived from the
/// decoded coordinates so every codec (including lossy ones) anchors on
/// values that actually exist in its output.
fn query_boxes(full: &Snapshot) -> Vec<(&'static str, Region)> {
    let ext = |a: usize| -> (f32, f32) {
        let f = &full.fields[a];
        (
            f.iter().copied().fold(f32::MAX, f32::min),
            f.iter().copied().fold(f32::MIN, f32::max),
        )
    };
    let (x0, x1) = ext(0);
    let (y0, y1) = ext(1);
    let (z0, z1) = ext(2);
    // Anchor the interior box on a real particle near the middle of the
    // archive's order, a tenth of the domain wide per axis.
    let i = full.len() / 2;
    let p = [full.fields[0][i], full.fields[1][i], full.fields[2][i]];
    let d = [
        ((x1 - x0) / 10.0).max(1e-3),
        ((y1 - y0) / 10.0).max(1e-3),
        ((z1 - z0) / 10.0).max(1e-3),
    ];
    vec![
        (
            "interior",
            Region::new(
                [p[0] - d[0], p[1] - d[1], p[2] - d[2]],
                [p[0] + d[0], p[1] + d[1], p[2] + d[2]],
            )
            .unwrap(),
        ),
        (
            // One face flush with the domain edge, clipping a slab.
            "face-clipping",
            Region::new([x0, y0, z0], [x0 + (x1 - x0) / 3.0, y1 + 1.0, z1 + 1.0]).unwrap(),
        ),
        (
            "empty",
            Region::new([x1 + 1e3, y1 + 1e3, z1 + 1e3], [x1 + 2e3, y1 + 2e3, z1 + 2e3]).unwrap(),
        ),
        (
            "full-domain",
            Region::new([f32::MIN / 2.0; 3], [f32::MAX / 2.0; 3]).unwrap(),
        ),
    ]
}

#[test]
fn full_lineup_region_queries_match_brute_force() {
    let snap = generate_md(&MdConfig {
        n_particles: 5_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let ctx = ExecCtx::with_threads(2);
    for name in full_lineup() {
        let spec = registry::canonical(name).unwrap();
        for layout in ["spatial", "cost"] {
            let path = dir.join(format!("nblc_region_{pid}_{name}_{layout}.nblc"));
            match layout {
                "spatial" => {
                    build_spatial_archive(&path, &snap, &spec, 5, 512);
                }
                _ => build_cost_archive(&path, &snap, &spec, 5),
            }
            let reader = ShardReader::open(&path).unwrap();
            // Membership is defined on decoded coordinates.
            let full = decode_shards(&reader, reader.spec(), None, &ctx)
                .unwrap()
                .snapshot;
            let sp = reader.spatial().cloned();
            assert_eq!(sp.is_some(), layout == "spatial", "{name} {layout}");
            let nonempty = reader
                .index()
                .entries
                .iter()
                .filter(|e| e.start < e.end)
                .count();
            for (shape, r) in query_boxes(&full) {
                let what = format!("{name} {layout} {shape}");
                let (touched, pruned, indexed) = check_region(&reader, &full, &r, &ctx, &what);
                assert_eq!(indexed, layout == "spatial", "{what}");
                match &sp {
                    Some(sp) => {
                        // Touched is bounded by the bbox-overlap count —
                        // segment boxes only ever tighten it.
                        let overlap = reader
                            .index()
                            .entries
                            .iter()
                            .enumerate()
                            .filter(|(i, e)| e.start < e.end && r.intersects(&sp.shards[*i].bbox))
                            .count();
                        assert!(touched <= overlap, "{what}: {touched} > overlap {overlap}");
                        assert_eq!(touched + pruned, nonempty, "{what}");
                        if shape == "empty" {
                            assert_eq!(touched, 0, "{what}: far box must decode nothing");
                        }
                        if shape == "full-domain" {
                            assert_eq!(pruned, 0, "{what}");
                        }
                    }
                    None => {
                        assert_eq!(touched, nonempty, "{what}: fallback scans everything");
                        assert_eq!(pruned, 0, "{what}");
                    }
                }
            }
            std::fs::remove_file(&path).ok();
        }
    }
}

#[test]
fn sixteen_shard_interior_box_decodes_at_most_two_shards_cli_and_serve() {
    const SHARDS: usize = 16;
    let snap = generate_md(&MdConfig {
        n_particles: 40_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let path = dir.join(format!("nblc_region16_{}.nblc", std::process::id()));
    let spec = registry::canonical("sz_lv").unwrap();
    let (_, index) = build_spatial_archive(&path, &snap, &spec, SHARDS, 1_024);
    let sp = index.spatial.as_ref().unwrap();
    let reader = ShardReader::open(&path).unwrap();
    let ctx = ExecCtx::with_threads(2);
    let full = decode_shards(&reader, reader.spec(), None, &ctx)
        .unwrap()
        .snapshot;
    let nonempty: Vec<usize> = index
        .entries
        .iter()
        .enumerate()
        .filter(|(_, e)| e.start < e.end)
        .map(|(i, _)| i)
        .collect();
    assert!(nonempty.len() >= 12, "layout degenerated: {nonempty:?}");

    // Tiny boxes around particles deep inside each shard; pick one the
    // bbox index says ≤2 shards overlap (Morton shards are compact, so
    // such particles are plentiful — but don't hardcode which).
    let tiny = {
        let f = &full.fields[0];
        let (lo, hi) = (
            f.iter().copied().fold(f32::MAX, f32::min),
            f.iter().copied().fold(f32::MIN, f32::max),
        );
        ((hi - lo) / 100.0).max(1e-3)
    };
    let mut pick: Option<Region> = None;
    for &si in &nonempty {
        let e = &index.entries[si];
        let i = ((e.start + e.end) / 2) as usize;
        let p = [full.fields[0][i], full.fields[1][i], full.fields[2][i]];
        let r = Region::new(
            [p[0] - tiny, p[1] - tiny, p[2] - tiny],
            [p[0] + tiny, p[1] + tiny, p[2] + tiny],
        )
        .unwrap();
        let overlap = nonempty
            .iter()
            .filter(|&&j| r.intersects(&sp.shards[j].bbox))
            .count();
        if overlap <= 2 {
            pick = Some(r);
            break;
        }
    }
    let r =
        pick.expect("no interior box overlapping ≤2 of 16 Morton shards — index is not spatial");

    // Library ("CLI") path: exactly the overlapping shards, nothing else.
    let dec = decode_region(&reader, reader.spec(), &r, &ctx).unwrap();
    assert!(dec.indexed);
    assert!(
        (1..=2).contains(&dec.shards_touched),
        "interior box decoded {} shards",
        dec.shards_touched
    );
    assert_eq!(dec.shards_touched + dec.shards_pruned, nonempty.len());
    assert!(dec.shards_pruned >= nonempty.len() - 2);
    let keep = brute(&full, &r);
    assert_eq!(dec.snapshot.len(), keep.len());
    assert!(!keep.is_empty(), "anchor particle must be inside its own box");

    // Serve path: same counters and the same bytes over the wire, and
    // region replies ride the shard LRU (a repeat hits the cache).
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_mb: 64,
        max_inflight: 4,
        queue_timeout_ms: 5_000,
        decode_budget_ms: 0,
        threads: 2,
    };
    let handle = Server::bind(&cfg, &[&path]).unwrap().spawn();
    let addr = handle.addr();
    let mut client = ServeClient::connect(addr).unwrap();
    let served = loop {
        match client.get_region("", r.min, r.max).unwrap() {
            GetReply::Data(d) => break d,
            GetReply::Busy(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    };
    assert!(served.region, "reply must be flagged as a region result");
    assert_eq!(served.shards_touched as usize, dec.shards_touched);
    assert_eq!(served.shards_pruned as usize, dec.shards_pruned);
    assert_eq!(
        bits_of(&served.snapshot),
        bits_of(&dec.snapshot),
        "served region bytes differ from the direct decode"
    );
    let again = match client.get_region("", r.min, r.max).unwrap() {
        GetReply::Data(d) => d,
        GetReply::Busy(b) => panic!("warm repeat shed: {b:?}"),
    };
    assert!(again.cache_hits > 0, "repeat region read must hit the LRU");
    assert_eq!(bits_of(&again.snapshot), bits_of(&served.snapshot));

    // Pruning is visible in the daemon's stats.
    let stats = client.stats().unwrap();
    assert_eq!(stats.region_requests, 2);
    assert_eq!(stats.shards_pruned, 2 * dec.shards_pruned as u64);

    // A malformed box is a typed server error, and the daemon survives.
    assert!(client.get_region("", [1.0, 0.0, 0.0], [0.0, 1.0, 1.0]).is_err());
    let mut client = ServeClient::connect(addr).unwrap();
    let ok = client.get_region("", r.min, r.max).unwrap();
    assert!(matches!(ok, GetReply::Data(_)), "daemon wedged after bad region");

    handle.stop();
    std::fs::remove_file(&path).ok();
}

#[test]
fn pre_spatial_archives_answer_region_queries_via_serve_fallback() {
    // A cost-layout archive served over the wire: region queries still
    // answer exactly (full-scan), with zero pruned and `region` flagged.
    let snap = generate_md(&MdConfig {
        n_particles: 4_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let path = dir.join(format!("nblc_region_fallback_{}.nblc", std::process::id()));
    let spec = registry::canonical("sz_lv").unwrap();
    build_cost_archive(&path, &snap, &spec, 4);
    let reader = ShardReader::open(&path).unwrap();
    let ctx = ExecCtx::sequential();
    let full = decode_shards(&reader, reader.spec(), None, &ctx)
        .unwrap()
        .snapshot;
    let (_, r) = query_boxes(&full).remove(0);

    let handle = Server::bind(
        &ServeConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        &[&path],
    )
    .unwrap()
    .spawn();
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    let d = loop {
        match client.get_region("", r.min, r.max).unwrap() {
            GetReply::Data(d) => break d,
            GetReply::Busy(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    };
    assert!(d.region);
    assert_eq!(d.shards_pruned, 0, "no index, nothing pruned");
    assert_eq!(d.shards_touched, 4, "fallback decodes every shard");
    let keep = brute(&full, &r);
    assert_eq!(d.snapshot.len(), keep.len());
    for f in 0..6 {
        let want: Vec<u32> = keep.iter().map(|&i| full.fields[f][i].to_bits()).collect();
        let got: Vec<u32> = d.snapshot.fields[f].iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "field {f}");
    }
    handle.stop();
    std::fs::remove_file(&path).ok();
}
