//! Acceptance-criteria integration test: pipeline-compress with the
//! FULL codec lineup × shard counts {1, 4, 7} into a sharded v3
//! archive, reopen with `ShardReader`, decode both fully (parallel
//! shard fan-out) and via a partial `--particles`-style range, and
//! verify the configured error bound holds — including the RX-family
//! reordering codecs, whose shards are stitched back each in its own
//! deterministic sort order. Shard-touch counters pin the partial-read
//! guarantee: only shards overlapping the range are fetched.

use nblc::compressors::{full_lineup, registry};
use nblc::coordinator::pipeline::{run_insitu, InsituConfig, Sink};
use nblc::data::archive::{decode_shards, ShardReader};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::exec::ExecCtx;
use nblc::quality::Quality;
use nblc::snapshot::{verify_bounds, Snapshot};

const N: usize = 7_000;
const EB: f64 = 1e-4;

/// What a shard decodes to, modulo the codec's deterministic
/// per-shard permutation (identity for order-preserving codecs).
fn shard_reference(spec: &str, sub: &Snapshot) -> Snapshot {
    match registry::sort_permutation(spec, sub, EB).unwrap() {
        Some(perm) => sub.permute(&perm).unwrap(),
        None => sub.clone(),
    }
}

#[test]
fn full_lineup_roundtrips_through_sharded_pipeline_archive() {
    let snap = generate_md(&MdConfig {
        n_particles: N,
        ..Default::default()
    });
    let ctx = ExecCtx::with_threads(2);
    let dir = std::env::temp_dir();
    for name in full_lineup() {
        let spec = registry::canonical(name).unwrap();
        for shards in [1usize, 4, 7] {
            let tag = format!("{name}/shards={shards}");
            let path = dir.join(format!(
                "nblc_pipe_rt_{}_{name}_{shards}.nblc",
                std::process::id()
            ));
            let report = run_insitu(
                &snap,
                &InsituConfig {
                    shards,
                    layout: None,
                    workers: 2,
                    threads: 1,
                    queue_depth: 2,
                    quality: Quality::rel(EB),
                    factory: registry::factory(&spec).unwrap(),
                    sink: Sink::Archive {
                        path: path.clone(),
                        spec: spec.clone(),
                    },
                    spatial: None,
                    max_retries: 0,
                    sink_fault: None,
                },
            )
            .unwrap_or_else(|e| panic!("{tag}: pipeline failed: {e}"));
            assert_eq!(
                report.shard_index.as_ref().map(|i| i.entries.len()),
                Some(shards),
                "{tag}: footer shard count"
            );

            let reader =
                ShardReader::open(&path).unwrap_or_else(|e| panic!("{tag}: open failed: {e}"));
            assert_eq!(reader.n() as usize, snap.len(), "{tag}");
            assert_eq!(reader.spec(), spec, "{tag}: archived spec");
            reader
                .verify_file_crc()
                .unwrap_or_else(|e| panic!("{tag}: file CRC: {e}"));

            // ---- Full decode, shard fan-out across threads. ----
            let dec = decode_shards(&reader, reader.spec(), None, &ctx)
                .unwrap_or_else(|e| panic!("{tag}: full decode failed: {e}"));
            assert_eq!(dec.shards_touched, shards, "{tag}");
            assert_eq!(dec.snapshot.len(), snap.len(), "{tag}");
            // fpzip is precision-based: it lands *near* the requested
            // bound, not strictly under it (paper §IV) — skip the
            // bound assertion, keep the structural ones.
            if name != "fpzip" {
                for e in &reader.index().entries {
                    let sub = snap.slice(e.start as usize, e.end as usize);
                    let reference = shard_reference(&spec, &sub);
                    let got = dec.snapshot.slice(e.start as usize, e.end as usize);
                    verify_bounds(&reference, &got, EB)
                        .unwrap_or_else(|err| panic!("{tag}: full-decode bound: {err}"));
                }
            }

            // ---- Partial read over a mid-snapshot window. ----
            let (a, b) = (2_500u64, 4_200u64);
            let part = decode_shards(&reader, reader.spec(), Some((a, b)), &ctx)
                .unwrap_or_else(|e| panic!("{tag}: partial decode failed: {e}"));
            // Shard-touch counter: exactly the overlapping shards.
            let touched: Vec<usize> = reader.shards_for_range(a, b);
            assert_eq!(part.shards_touched, touched.len(), "{tag}");
            if shards > 1 {
                assert!(
                    part.shards_touched < shards,
                    "{tag}: a partial read must not touch all {shards} shards"
                );
            }
            if name == "fpzip" {
                std::fs::remove_file(&path).ok();
                continue;
            }
            if part.reordered {
                // Whole touched shards come back, each internally in
                // its deterministic per-shard sort order.
                let cover_start = part.particle_start;
                for &i in &touched {
                    let e = &reader.index().entries[i];
                    let sub = snap.slice(e.start as usize, e.end as usize);
                    let reference = shard_reference(&spec, &sub);
                    let got = part.snapshot.slice(
                        (e.start - cover_start) as usize,
                        (e.end - cover_start) as usize,
                    );
                    verify_bounds(&reference, &got, EB)
                        .unwrap_or_else(|err| panic!("{tag}: partial-decode bound: {err}"));
                }
            } else {
                // Order-preserving codecs trim exactly to [a, b); each
                // particle must sit within the eb derived from the
                // value range of ITS shard (what the compressor used).
                assert!(part.exact, "{tag}");
                assert_eq!(part.snapshot.len(), (b - a) as usize, "{tag}");
                for &i in &touched {
                    let e = &reader.index().entries[i];
                    let ebs = snap.slice(e.start as usize, e.end as usize).abs_bounds(EB);
                    let lo = a.max(e.start);
                    let hi = b.min(e.end);
                    for f in 0..6 {
                        for g in lo..hi {
                            let orig = snap.fields[f][g as usize] as f64;
                            let got = part.snapshot.fields[f][(g - a) as usize] as f64;
                            assert!(
                                (orig - got).abs() <= ebs[f],
                                "{tag}: field {f} particle {g}: |{orig} - {got}| > {}",
                                ebs[f]
                            );
                        }
                    }
                }
            }
            std::fs::remove_file(&path).ok();
        }
    }
}
