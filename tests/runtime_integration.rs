//! Integration: AOT artifacts -> PJRT -> Rust SZ entropy stage.
//!
//! These tests exercise the full three-layer bridge (Pallas kernel
//! lowered to HLO, compiled by the CPU PJRT client, driven from Rust)
//! and are skipped with a notice when `artifacts/` has not been built
//! (`make artifacts`).

use nblc::compressors::sz::Sz;
use nblc::data::gen_cosmo::{generate_cosmo, CosmoConfig};
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::runtime::{PjrtQuantizer, Runtime};
use nblc::snapshot::FieldCompressor;
use nblc::util::stats::value_range;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::load_default() {
        Some(rt) => Some(Arc::new(rt)),
        None => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn test_field(n: usize) -> Vec<f32> {
    let s = generate_cosmo(&CosmoConfig {
        n_particles: n,
        ..Default::default()
    });
    s.fields[2].clone() // zz: piecewise-smooth with jumps
}

#[test]
fn pjrt_codes_reconstruct_within_bound() {
    let Some(rt) = runtime() else { return };
    let q = PjrtQuantizer::new(rt);
    for n in [1000usize, 262144, 300_000] {
        let xs = test_field(n);
        let eb = value_range(&xs) * 1e-4;
        for pred in [Predictor::LastValue, Predictor::LinearCurveFit] {
            let codes = q.quantize(&xs, eb, pred).unwrap();
            assert_eq!(codes.codes.len(), n);
            assert_eq!(codes.codes[0], 0);
            let native = LatticeQuantizer::new(eb).unwrap();
            let recon = native.reconstruct(&codes);
            for (i, (&a, &b)) in xs.iter().zip(recon.iter()).enumerate() {
                let err = (a as f64 - b as f64).abs();
                assert!(err <= eb, "n={n} pred={pred:?} i={i} err={err:e} eb={eb:e}");
            }
        }
    }
}

#[test]
fn pjrt_codes_match_native_at_paper_bound() {
    // At eb_rel = 1e-4 the lattice fits comfortably in f32, so the
    // kernel's codes must be identical to the native f64 quantizer's.
    let Some(rt) = runtime() else { return };
    let q = PjrtQuantizer::new(rt);
    let xs = test_field(262144);
    let eb = value_range(&xs) * 1e-4;
    let pjrt_codes = q.quantize(&xs, eb, Predictor::LastValue).unwrap();
    let native = LatticeQuantizer::new(eb).unwrap();
    let native_codes = native.quantize(&xs, Predictor::LastValue);
    let diff = pjrt_codes
        .codes
        .iter()
        .zip(native_codes.codes.iter())
        .filter(|(a, b)| a != b)
        .count();
    // f32 vs f64 rounding can flip ties on a tiny fraction of elements.
    assert!(
        diff as f64 <= xs.len() as f64 * 1e-3,
        "{diff} / {} codes differ",
        xs.len()
    );
}

#[test]
fn pjrt_dequantize_roundtrip() {
    let Some(rt) = runtime() else { return };
    let q = PjrtQuantizer::new(rt);
    let xs = test_field(300_000); // forces multi-chunk path
    let eb = value_range(&xs) * 1e-4;
    // The graph evaluates the lattice in f32 (step rounded once), so
    // allow one f32 ULP of slop on top of the bound; the *authoritative*
    // decoder is the native f64 path tested above.
    let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs())) as f64;
    let tol = eb + max_abs * f32::EPSILON as f64;
    for pred in [Predictor::LastValue, Predictor::LinearCurveFit] {
        let codes = q.quantize(&xs, eb, pred).unwrap();
        let recon = q.dequantize(&codes).unwrap();
        assert_eq!(recon.len(), xs.len());
        for (i, (&a, &b)) in xs.iter().zip(recon.iter()).enumerate() {
            let err = (a as f64 - b as f64).abs();
            assert!(err <= tol, "pred={pred:?} i={i} err={err:e} tol={tol:e}");
        }
    }
}

#[test]
fn pjrt_streams_decode_with_native_sz() {
    // Production path: PJRT-produced streams must be byte-compatible
    // with the plain SZ decoder.
    let Some(rt) = runtime() else { return };
    let sz_pjrt = nblc::runtime::quantizer::SzPjrt::lv(rt);
    let xs = test_field(100_000);
    let eb = value_range(&xs) * 1e-4;
    let bytes = sz_pjrt.compress(&xs, eb).unwrap();
    let back = Sz::lv().decompress(&bytes).unwrap();
    assert_eq!(back.len(), xs.len());
    for (&a, &b) in xs.iter().zip(back.iter()) {
        assert!((a as f64 - b as f64).abs() <= eb);
    }
}

#[test]
fn pjrt_metrics_graph_matches_cpu() {
    let Some(rt) = runtime() else { return };
    let n = rt.meta("field_metrics").unwrap().n;
    let xs = test_field(n);
    let mut ys = xs.clone();
    for (i, y) in ys.iter_mut().enumerate() {
        *y += (i % 7) as f32 * 1e-3;
    }
    let x_lit = xla::Literal::vec1(&xs);
    let y_lit = xla::Literal::vec1(&ys);
    let out = rt.execute("field_metrics", &[x_lit, y_lit]).unwrap();
    let sse: Vec<f32> = out[0].to_vec().unwrap();
    let maxerr: Vec<f32> = out[1].to_vec().unwrap();
    let want_sse: f64 = xs
        .iter()
        .zip(ys.iter())
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    let want_max = xs
        .iter()
        .zip(ys.iter())
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!((sse[0] as f64 - want_sse).abs() / want_sse.max(1e-12) < 1e-3);
    assert!((maxerr[0] - want_max).abs() < 1e-6);
}
