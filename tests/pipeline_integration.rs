//! Integration: config file -> validated settings -> sharded in-situ
//! pipeline -> per-shard streams decode within bound; plus the
//! rebalancing loop over observed shard costs.

use nblc::compressors::{registry, Mode};
use nblc::config::{ConfigDoc, PipelineSettings};
use nblc::coordinator::pipeline::{run_insitu, CompressorFactory, InsituConfig, Sink};
use nblc::coordinator::shard::{rebalance, split_even, Shard};
use nblc::coordinator::GpfsModel;
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::quality::Quality;
use nblc::snapshot::{verify_bounds, PerField, SnapshotCompressor};

fn factory_for(mode: Mode) -> CompressorFactory {
    registry::factory(&mode.spec()).expect("mode spec is registry-valid")
}

#[test]
fn config_to_pipeline_roundtrip() {
    let doc = ConfigDoc::parse(
        r#"
        [pipeline]
        dataset = "amdf"
        particles = 80000
        shards = 8
        workers = 2
        queue_depth = 2
        eb_rel = 1e-4
        mode = "best_speed"
        sim_procs = 256
        "#,
    )
    .unwrap();
    let settings = PipelineSettings::from_doc(&doc).unwrap();
    let snap = generate_md(&MdConfig {
        n_particles: settings.particles,
        ..Default::default()
    });
    let report = run_insitu(
        &snap,
        &InsituConfig {
            shards: settings.shards,
            layout: None,
            workers: settings.workers,
            threads: settings.threads,
            queue_depth: settings.queue_depth,
            quality: settings.quality.clone(),
            factory: factory_for(settings.mode),
            sink: Sink::Model {
                model: GpfsModel::default(),
                procs: settings.sim_procs,
            },
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    assert_eq!(report.bytes_in, snap.total_bytes() as u64);
    assert!(report.ratio > 2.0, "ratio {}", report.ratio);
    assert!(report.sink_secs > 0.0);
    assert_eq!(report.shard_ratios.len(), 8);
}

#[test]
fn config_method_spec_drives_pipeline() {
    // An explicit parameterized codec spec in the config feeds the
    // registry factory directly (the `method` key overrides `mode`).
    let doc = ConfigDoc::parse(
        r#"
        [pipeline]
        dataset = "amdf"
        particles = 30000
        shards = 4
        workers = 2
        queue_depth = 2
        eb_rel = 1e-4
        method = "sz_lv_rx:segment=2048"
        "#,
    )
    .unwrap();
    let settings = PipelineSettings::from_doc(&doc).unwrap();
    let spec = settings.method.as_deref().expect("method key parsed");
    let snap = generate_md(&MdConfig {
        n_particles: settings.particles,
        ..Default::default()
    });
    let report = run_insitu(
        &snap,
        &InsituConfig {
            shards: settings.shards,
            layout: None,
            workers: settings.workers,
            threads: settings.threads,
            queue_depth: settings.queue_depth,
            quality: settings.quality.clone(),
            factory: registry::factory(spec).unwrap(),
            sink: Sink::Null,
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    assert_eq!(report.bytes_in, snap.total_bytes() as u64);
    assert!(report.ratio > 1.5, "ratio {}", report.ratio);
}

#[test]
fn every_shard_stream_decodes_within_bound() {
    // What a reader of the pipeline's output does: decode each shard
    // independently and check the bound against the matching slice.
    let snap = generate_md(&MdConfig {
        n_particles: 40_000,
        ..Default::default()
    });
    let eb_rel = 1e-4;
    let comp = PerField(nblc::compressors::sz::Sz::lv());
    for shard in split_even(snap.len(), 5) {
        let sub = snap.slice(shard.start, shard.end);
        let bundle = comp.compress(&sub, &Quality::rel(eb_rel)).unwrap();
        let recon = comp.decompress(&bundle).unwrap();
        verify_bounds(&sub, &recon, eb_rel).unwrap();
    }
}

#[test]
fn rebalance_feedback_loop_converges() {
    // Feed observed per-shard costs back into the splitter: shards with
    // higher per-particle cost should shrink, and a second round with
    // uniform costs should stay put.
    let n = 120_000;
    let shards = split_even(n, 6);
    // Pretend shard 0 and 1 are twice as expensive.
    let costs = [2.0, 2.0, 1.0, 1.0, 1.0, 1.0];
    let round2 = rebalance(&shards, &costs);
    assert_eq!(round2.last().unwrap().end, n);
    assert!(round2[0].len() < shards[0].len());
    assert!(round2[5].len() > shards[5].len());
    // Contiguity invariant.
    for w in round2.windows(2) {
        assert_eq!(w[0].end, w[1].start);
    }
    // Cost-balance: predicted cost spread under 15%.
    let pred = |s: &Shard, c: f64| s.len() as f64 * c;
    let preds: Vec<f64> = round2
        .iter()
        .map(|s| {
            // map the new shard to the dominant old density region
            let mid = (s.start + s.end) / 2;
            let old = shards.iter().position(|o| mid < o.end).unwrap();
            pred(s, costs[old])
        })
        .collect();
    let max = preds.iter().cloned().fold(0.0, f64::max);
    let min = preds.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(max / min < 1.35, "cost spread {max}/{min}");
}

#[test]
fn rebalanced_layout_round_trips_through_pipeline_and_archive() {
    // The `[pipeline] rebalance` path: round 1 with an even split, feed
    // the observed per-shard cost counters back into the splitter, and
    // run round 2 with the recut layout — writing a v3 archive whose
    // footer reflects the new boundaries.
    let snap = generate_md(&MdConfig {
        n_particles: 50_000,
        ..Default::default()
    });
    let factory = registry::factory("sz_lv").unwrap();
    let round1 = run_insitu(
        &snap,
        &InsituConfig {
            shards: 5,
            layout: None,
            workers: 2,
            threads: 1,
            queue_depth: 2,
            quality: Quality::rel(1e-4),
            factory: factory.clone(),
            sink: Sink::Null,
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    let costs = round1.cost_per_particle();
    assert_eq!(costs.len(), 5);
    let layout2 = rebalance(&round1.layout, &costs);
    let path = std::env::temp_dir().join(format!("nblc_rebal_{}.nblc", std::process::id()));
    let round2 = run_insitu(
        &snap,
        &InsituConfig {
            shards: 5,
            layout: Some(layout2.clone()),
            workers: 2,
            threads: 1,
            queue_depth: 2,
            quality: Quality::rel(1e-4),
            factory,
            sink: Sink::Archive {
                path: path.clone(),
                spec: registry::canonical("sz_lv").unwrap(),
            },
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    assert_eq!(round2.layout, layout2);
    let index = round2.shard_index.expect("archive sink returns footer");
    // The footer's logical table mirrors the rebalanced boundaries and
    // carries the per-shard cost counters for the *next* round.
    assert_eq!(index.entries.len(), layout2.len());
    for (e, sh) in index.entries.iter().zip(&layout2) {
        assert_eq!((e.start as usize, e.end as usize), (sh.start, sh.end));
    }
    assert!(index.entries.iter().any(|e| e.cost_nanos > 0));
    // And the archive still decodes within bound per shard.
    let reader = nblc::data::archive::ShardReader::open(&path).unwrap();
    let dec = nblc::data::archive::decode_shards(
        &reader,
        reader.spec(),
        None,
        &nblc::exec::ExecCtx::with_threads(2),
    )
    .unwrap();
    std::fs::remove_file(&path).ok();
    for sh in &layout2 {
        verify_bounds(
            &snap.slice(sh.start, sh.end),
            &dec.snapshot.slice(sh.start, sh.end),
            1e-4,
        )
        .unwrap();
    }
}

#[test]
fn scheduler_routing_via_pipeline() {
    // The pipeline run with auto-routed mode must out-compress the
    // unrouted R-index mode on cosmology data.
    let snap = nblc::data::gen_cosmo::generate_cosmo(&nblc::data::gen_cosmo::CosmoConfig {
        n_particles: 100_000,
        ..Default::default()
    });
    let routed = nblc::coordinator::choose_compressor(&snap, Mode::BestCompression);
    assert_eq!(routed, Mode::BestSpeed);
    let r1 = run_insitu(
        &snap,
        &InsituConfig {
            shards: 4,
            layout: None,
            workers: 1,
            threads: 1,
            queue_depth: 2,
            quality: Quality::rel(1e-4),
            factory: factory_for(routed),
            sink: Sink::Null,
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    let r2 = run_insitu(
        &snap,
        &InsituConfig {
            shards: 4,
            layout: None,
            workers: 1,
            threads: 1,
            queue_depth: 2,
            quality: Quality::rel(1e-4),
            factory: factory_for(Mode::BestCompression),
            sink: Sink::Null,
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    assert!(
        r1.ratio > r2.ratio,
        "routed {} must beat unrouted {}",
        r1.ratio,
        r2.ratio
    );
}
