//! Acceptance-criteria integration test for `nblc serve`: a daemon on
//! a loopback ephemeral port serving the full codec lineup, hammered
//! by concurrent clients with overlapping and disjoint ranges. Every
//! reply must be bitwise identical to a direct `ShardReader` decode,
//! repeats must hit the LRU cache, an undersized `max_inflight` must
//! shed with a typed `Busy` (never a hang or panic), and hostile wire
//! bytes must get typed error frames with clean connection handling.

use nblc::compressors::{full_lineup, registry};
use nblc::data::archive::{decode_shards, ShardReader, ShardWriter};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::exec::ExecCtx;
use nblc::quality::Quality;
use nblc::serve::protocol::{
    read_frame_or_eof, write_frame, Request, Response, FRAME_MAGIC, MAX_RESPONSE_FRAME, REQ_GET,
};
use nblc::serve::{GetReply, RangeData, ServeClient, ServeConfig, Server};
use nblc::snapshot::Snapshot;
use std::io::Write;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

const EB: f64 = 1e-4;

fn build_archive(path: &Path, snap: &Snapshot, spec: &str, shards: usize) {
    let quality = Quality::rel(EB);
    let comp = registry::build_str(spec).unwrap();
    let mut w = ShardWriter::create_quality(path, spec, &quality).unwrap();
    let n = snap.len();
    for s in 0..shards {
        let (start, end) = (s * n / shards, (s + 1) * n / shards);
        let bundle = comp.compress(&snap.slice(start, end), &quality).unwrap();
        // Nonzero cost counters so admission estimates have substance.
        w.write_shard(start, end, &bundle, 2_000_000).unwrap();
    }
    w.finish().unwrap();
}

/// Get with bounded retry-on-busy, so a loaded CI box never flakes.
fn get_ok(client: &mut ServeClient, archive: &str, range: Option<(u64, u64)>) -> RangeData {
    for _ in 0..200 {
        match client.get(archive, range).unwrap() {
            GetReply::Data(d) => return d,
            GetReply::Busy(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    panic!("server stayed busy for {archive}");
}

fn bits(s: &Snapshot) -> Vec<Vec<u32>> {
    s.fields
        .iter()
        .map(|f| f.iter().map(|v| v.to_bits()).collect())
        .collect()
}

#[test]
fn concurrent_range_reads_match_direct_decodes_across_the_lineup() {
    let snap = generate_md(&MdConfig {
        n_particles: 6_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut names: Vec<String> = Vec::new();
    for name in full_lineup() {
        let spec = registry::canonical(name).unwrap();
        let fname = format!("nblc_serve_{pid}_{name}.nblc");
        let path = dir.join(&fname);
        build_archive(&path, &snap, &spec, 4);
        paths.push(path);
        names.push(fname);
    }
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_mb: 64,
        max_inflight: 8,
        queue_timeout_ms: 5_000,
        decode_budget_ms: 0,
        threads: 2,
    };
    let handle = Server::bind(&cfg, &paths).unwrap().spawn();
    let addr = handle.addr();

    // Overlapping and disjoint windows, plus full reads.
    let ranges: [Option<(u64, u64)>; 4] =
        [None, Some((1_000, 2_500)), Some((2_000, 4_800)), Some((4_600, 6_000))];
    let seq = ExecCtx::sequential();
    std::thread::scope(|scope| {
        for (name, path) in names.iter().zip(&paths) {
            for range in ranges {
                let seq = &seq;
                scope.spawn(move || {
                    let mut client = ServeClient::connect(addr).unwrap();
                    let d = get_ok(&mut client, name, range);
                    let reader = ShardReader::open(path).unwrap();
                    let direct = decode_shards(&reader, reader.spec(), range, seq).unwrap();
                    assert_eq!(d.particle_start, direct.particle_start, "{name} {range:?}");
                    assert_eq!(d.particle_end, direct.particle_end, "{name} {range:?}");
                    assert_eq!(d.exact, direct.exact, "{name} {range:?}");
                    assert_eq!(d.reordered, direct.reordered, "{name} {range:?}");
                    assert_eq!(
                        d.shards_touched as usize, direct.shards_touched,
                        "{name} {range:?}"
                    );
                    assert_eq!(
                        bits(&d.snapshot),
                        bits(&direct.snapshot),
                        "{name} {range:?}: served bytes differ from direct decode"
                    );
                });
            }
        }
    });

    // Repeats are served from the LRU cache.
    let mut client = ServeClient::connect(addr).unwrap();
    let d = get_ok(&mut client, &names[0], Some((1_000, 2_500)));
    assert!(
        d.cache_hits > 0,
        "repeat read of a hot range must hit the cache"
    );
    let stats = client.stats().unwrap();
    assert!(stats.cache_hits > 0);
    assert!(stats.cache_misses > 0);
    assert_eq!(stats.busy + stats.data_ok + stats.errors + 1, stats.requests);
    assert!(
        stats.data_ok >= (names.len() * ranges.len()) as u64,
        "every scoped request must eventually have been answered with data"
    );
    assert_eq!(stats.archives.len(), names.len());
    for (name, touches) in &stats.archives {
        assert!(*touches > 0, "archive {name} was never touched");
    }
    assert!(stats.inflight_high_water >= 1);

    handle.stop();
    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn cold_start_stampede_runs_exactly_one_decode() {
    // One big shard, eight concurrent clients asking for the same
    // range the instant the server comes up. Single-flight coalescing
    // must collapse the stampede onto a single decode: one cache miss,
    // everyone else a hit or a coalesced join of the in-flight decode.
    let snap = generate_md(&MdConfig {
        n_particles: 200_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let path = dir.join(format!("nblc_serve_stampede_{}.nblc", std::process::id()));
    build_archive(&path, &snap, &registry::canonical("sz_lv").unwrap(), 1);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_mb: 64,
        max_inflight: 8, // every client is admitted; nothing sheds
        queue_timeout_ms: 30_000,
        decode_budget_ms: 0,
        threads: 2,
    };
    let handle = Server::bind(&cfg, &[&path]).unwrap().spawn();
    let addr = handle.addr();

    const CLIENTS: usize = 8;
    let barrier = std::sync::Barrier::new(CLIENTS);
    let replies: Vec<RangeData> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut client = ServeClient::connect(addr).unwrap();
                    barrier.wait();
                    get_ok(&mut client, "", Some((10_000, 150_000)))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Everyone got the same bytes.
    let first = bits(&replies[0].snapshot);
    for d in &replies[1..] {
        assert_eq!(bits(&d.snapshot), first, "stampede replies must agree");
    }

    let mut client = ServeClient::connect(addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(
        stats.cache_misses, 1,
        "a stampede on one shard must decode it exactly once: {stats:?}"
    );
    assert_eq!(
        stats.cache_hits + stats.cache_coalesced,
        (CLIENTS - 1) as u64,
        "every other lookup must be a hit or a coalesced join: {stats:?}"
    );
    assert_eq!(stats.data_ok, CLIENTS as u64);

    handle.stop();
    std::fs::remove_file(&path).ok();
}

#[test]
fn undersized_admission_sheds_with_typed_busy() {
    let snap = generate_md(&MdConfig {
        n_particles: 120_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let path = dir.join(format!("nblc_serve_busy_{}.nblc", std::process::id()));
    build_archive(&path, &snap, &registry::canonical("sz_lv").unwrap(), 2);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_mb: 1, // smaller than one decoded shard: every get decodes
        max_inflight: 1,
        queue_timeout_ms: 1,
        decode_budget_ms: 0,
        threads: 1,
    };
    let handle = Server::bind(&cfg, &[&path]).unwrap().spawn();
    let addr = handle.addr();

    let (mut data, mut busy) = (0u32, 0u32);
    std::thread::scope(|scope| {
        let replies: Vec<_> = (0..12)
            .map(|_| {
                scope.spawn(move || {
                    let mut client = ServeClient::connect(addr).unwrap();
                    client.get("", None).unwrap()
                })
            })
            .collect();
        for h in replies {
            match h.join().unwrap() {
                GetReply::Data(_) => data += 1,
                GetReply::Busy(b) => {
                    busy += 1;
                    assert_eq!(b.max_inflight, 1);
                    assert!(b.inflight >= 1);
                }
            }
        }
    });
    // The permit holder always finishes; with a 1 ms admission window
    // against multi-ms decodes, someone must have been shed.
    assert!(data >= 1, "at least one request must be admitted");
    assert!(busy >= 1, "over-budget load must shed with typed Busy");
    assert_eq!(data + busy, 12);

    // The daemon is still healthy afterwards.
    let mut client = ServeClient::connect(addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.busy, busy as u64);
    assert_eq!(stats.data_ok, data as u64);

    handle.stop();
    std::fs::remove_file(&path).ok();
}

#[test]
fn hostile_wire_input_gets_typed_errors_and_clean_closes() {
    let snap = generate_md(&MdConfig {
        n_particles: 2_000,
        ..Default::default()
    });
    let dir = std::env::temp_dir();
    let path = dir.join(format!("nblc_serve_hostile_{}.nblc", std::process::id()));
    build_archive(&path, &snap, &registry::canonical("sz_lv").unwrap(), 2);
    let handle = Server::bind(
        &ServeConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        &[&path],
    )
    .unwrap()
    .spawn();
    let addr = handle.addr();

    let expect_error_then_close = |raw: &[u8], what: &str| {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw).unwrap();
        s.flush().unwrap();
        let frame = read_frame_or_eof(&mut s, MAX_RESPONSE_FRAME).unwrap();
        let (kind, payload) = frame.unwrap_or_else(|| panic!("{what}: no error frame"));
        let resp = Response::decode(kind, &payload).unwrap();
        assert!(
            matches!(resp, Response::Error(_)),
            "{what}: expected error frame, got {resp:?}"
        );
        // The server closes after a protocol-level error: next read is
        // a clean EOF, not a hang.
        assert_eq!(read_frame_or_eof(&mut s, MAX_RESPONSE_FRAME).unwrap(), None, "{what}");
    };

    // Bad magic. Exactly four bytes, so the server has consumed every
    // byte we sent before it closes (a close with unread bytes pending
    // would RST and race the error frame past the client).
    expect_error_then_close(b"XXXX", "bad magic");
    // Oversized length prefix (u32::MAX) — rejected before allocating.
    let mut oversized = Vec::new();
    oversized.extend_from_slice(&FRAME_MAGIC);
    oversized.push(REQ_GET);
    oversized.extend_from_slice(&u32::MAX.to_le_bytes());
    expect_error_then_close(&oversized, "oversized length prefix");
    // Unknown frame kind.
    let mut unknown = Vec::new();
    write_frame(&mut unknown, 0x7f, b"").unwrap();
    expect_error_then_close(&unknown, "unknown request kind");
    // Garbage payload inside a well-formed frame.
    let mut garbage = Vec::new();
    write_frame(&mut garbage, REQ_GET, &[0xff; 16]).unwrap();
    expect_error_then_close(&garbage, "garbage get payload");

    // Truncated frame: close mid-header; server must just drop the
    // connection without wedging the accept loop.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&FRAME_MAGIC[..2]).unwrap();
        drop(s);
    }

    // Semantic errors keep the connection usable.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let send = |s: &mut TcpStream, req: &Request| {
            let (kind, payload) = req.encode();
            write_frame(s, kind, &payload).unwrap();
            let (kind, payload) = read_frame_or_eof(s, MAX_RESPONSE_FRAME).unwrap().unwrap();
            Response::decode(kind, &payload).unwrap()
        };
        let resp = send(
            &mut s,
            &Request::Get {
                archive: "nope.nblc".into(),
                range: None,
            },
        );
        assert!(matches!(resp, Response::Error(_)), "unknown archive: {resp:?}");
        let resp = send(
            &mut s,
            &Request::Get {
                archive: String::new(),
                range: Some((1_000_000, 2_000_000)), // out of bounds
            },
        );
        assert!(matches!(resp, Response::Error(_)), "oob range: {resp:?}");
        let resp = send(
            &mut s,
            &Request::Get {
                archive: String::new(),
                range: Some((500, 100)), // empty range
            },
        );
        assert!(matches!(resp, Response::Error(_)), "empty range: {resp:?}");
        // ...and a good request on the SAME connection still works.
        let resp = send(
            &mut s,
            &Request::Get {
                archive: String::new(),
                range: Some((100, 200)),
            },
        );
        assert!(matches!(resp, Response::Data(_)), "follow-up get: {resp:?}");
    }

    // The daemon survived everything above and still answers.
    let mut client = ServeClient::connect(addr).unwrap();
    let d = get_ok(&mut client, "", Some((0, 1_000)));
    assert_eq!(d.snapshot.len(), 1_000);
    let stats = client.stats().unwrap();
    assert!(stats.errors >= 6, "typed errors must be counted, got {}", stats.errors);

    handle.stop();
    std::fs::remove_file(&path).ok();
}
