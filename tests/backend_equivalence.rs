//! The SIMD backend's central contract: for EVERY registry codec, every
//! kernel backend the host can run, and every thread budget, compressed
//! output is byte-identical to the scalar sequential output, and
//! decoding with any backend reconstructs bit-identical snapshots.
//! Archives must never depend on which instruction set produced them —
//! `NBLC_SIMD` is a speed knob, not a format knob.

use nblc::compressors::{full_lineup, registry};
use nblc::data::gen_cosmo::{generate_cosmo, CosmoConfig};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::exec::ExecCtx;
use nblc::kernels::Kernels;
use nblc::quality::Quality;
use nblc::snapshot::{CompressedSnapshot, Snapshot};

const THREADS: [usize; 2] = [1, 8];

fn field_bits(s: &Snapshot) -> Vec<Vec<u32>> {
    s.fields
        .iter()
        .map(|f| f.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_bundle_eq(spec: &str, label: &str, threads: usize, a: &CompressedSnapshot, b: &CompressedSnapshot) {
    assert_eq!(
        a.fields.len(),
        b.fields.len(),
        "{spec}@{label}/{threads}t: stream count"
    );
    for (x, y) in a.fields.iter().zip(b.fields.iter()) {
        assert_eq!(x.name, y.name, "{spec}@{label}/{threads}t: field name");
        assert_eq!(
            x.bytes, y.bytes,
            "{spec}@{label}/{threads}t: field '{}' bytes differ from scalar",
            x.name
        );
    }
}

fn assert_backend_invariant(spec: &str, snap: &Snapshot, eb_rel: f64) {
    let comp = registry::build_str(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
    let quality = Quality::rel(eb_rel);
    let scalar_ctx = ExecCtx::with_threads(1).with_kernels(Kernels::scalar());
    let baseline = comp
        .compress_with(&scalar_ctx, snap, &quality)
        .unwrap_or_else(|e| panic!("{spec}: scalar compress failed: {e}"));
    let baseline_recon = comp
        .decompress_with(&scalar_ctx, &baseline)
        .unwrap_or_else(|e| panic!("{spec}: scalar decompress failed: {e}"));
    let baseline_bits = field_bits(&baseline_recon);
    for kern in Kernels::variants() {
        for threads in THREADS {
            let ctx = ExecCtx::with_threads(threads).with_kernels(kern);
            let out = comp
                .compress_with(&ctx, snap, &quality)
                .unwrap_or_else(|e| panic!("{spec}@{}/{threads}t: compress failed: {e}", kern.label));
            assert_bundle_eq(spec, kern.label, threads, &baseline, &out);
            // Cross-decode: bytes written by the scalar backend must
            // reconstruct identically on every backend.
            let recon = comp
                .decompress_with(&ctx, &baseline)
                .unwrap_or_else(|e| panic!("{spec}@{}/{threads}t: decompress failed: {e}", kern.label));
            assert_eq!(
                field_bits(&recon),
                baseline_bits,
                "{spec}@{}/{threads}t: reconstruction differs from scalar",
                kern.label
            );
        }
    }
}

#[test]
fn full_lineup_bytes_are_backend_invariant() {
    let md = generate_md(&MdConfig {
        n_particles: 4_000,
        ..Default::default()
    });
    for spec in full_lineup() {
        assert_backend_invariant(spec, &md, 1e-4);
    }
}

#[test]
fn tuned_specs_are_backend_invariant_on_cosmology_data() {
    // The orderly-coordinate dataset drives different code/escape
    // distributions through the quantizer and Huffman kernels, and the
    // segment parameters hit the radix-count kernel at many boundaries.
    let cosmo = generate_cosmo(&CosmoConfig {
        n_particles: 3_000,
        ..Default::default()
    });
    for spec in ["sz_lv", "sz_lv_rx:segment=256", "sz_lv_prx:segment=1024,ignore=4", "sz_cpc2000"] {
        assert_backend_invariant(spec, &cosmo, 1e-3);
    }
}

#[test]
fn adversarial_values_compress_identically_on_every_backend() {
    // The quantizer's hard cases: denormals, signed zeros, huge
    // magnitudes that blow up the value range, and near-midpoint
    // values where a backend using a different rounding rule (e.g.
    // hardware round-half-to-even) would diverge by one code.
    let mut md = generate_md(&MdConfig {
        n_particles: 4_096,
        ..Default::default()
    });
    for f in md.fields.iter_mut() {
        f[0] = f32::MIN_POSITIVE / 2.0; // subnormal
        f[1] = -0.0;
        f[2] = 1.0e30;
        f[3] = -1.0e30;
        f[4] = 0.5 + f32::EPSILON;
        f[5] = f32::MIN_POSITIVE;
        f[6] = -f32::MIN_POSITIVE / 4.0;
    }
    for spec in ["sz", "sz_lv", "sz_lv_rx", "sz_cpc2000"] {
        assert_backend_invariant(spec, &md, 1e-4);
    }
}

#[test]
fn variants_always_include_scalar_and_a_simd_table() {
    let variants = Kernels::variants();
    assert!(
        variants.iter().any(|k| k.label == "scalar"),
        "scalar must always be selectable"
    );
    assert!(
        variants.iter().any(|k| k.label.starts_with("simd")),
        "the portable SIMD table must always be selectable"
    );
    // Labels are distinct — selection and reporting rely on it.
    let mut labels: Vec<_> = variants.iter().map(|k| k.label).collect();
    labels.sort_unstable();
    labels.dedup();
    assert_eq!(labels.len(), variants.len(), "duplicate backend labels");
}
