//! Acceptance-criteria integration test for the temporal subsystem:
//! stream-compress leapfrog time series with keyframe+delta chains
//! across the three bound kinds × keyframe intervals {1, 4, 16} × the
//! order-preserving codec lineup, then pin the contract:
//!
//! - every timestep of the chain reconstructs within the configured
//!   quality bound — prediction runs off *decoded* state, so error
//!   never drifts no matter how deep the delta chain;
//! - `decode_timestep(t)` touches only t's keyframe group (shard-touch
//!   counters) and is bit-identical to an independent sequential replay
//!   of the whole chain;
//! - delta steps compress materially smaller than keyframes on
//!   velocity-coherent cosmology data;
//! - reordering codecs are rejected at stream-write AND decode time.

use nblc::compressors::registry;
use nblc::coordinator::pipeline::{run_insitu_stream, StreamConfig};
use nblc::data::archive::{decode_shards, ShardReader, ShardWriter};
use nblc::data::gen_cosmo::{self, CosmoConfig};
use nblc::exec::ExecCtx;
use nblc::quality::{verify_quality, Quality};
use nblc::snapshot::Snapshot;
use nblc::temporal::{predict, reconstruct, TemporalConfig};

const DT: f64 = 0.05;
const SHARDS: usize = 2;

fn series(n: usize, steps: usize) -> Vec<Snapshot> {
    gen_cosmo::time_series(
        &CosmoConfig {
            n_particles: n,
            ..Default::default()
        },
        steps,
        DT,
    )
}

fn stream(
    series: &[Snapshot],
    spec: &str,
    q: &Quality,
    interval: usize,
    tag: &str,
) -> (std::path::PathBuf, nblc::coordinator::pipeline::StreamReport) {
    let path = std::env::temp_dir().join(format!(
        "nblc_temporal_rt_{}_{}.nblc",
        std::process::id(),
        tag.replace(['/', ':', ' '], "_")
    ));
    let report = run_insitu_stream(
        series,
        &StreamConfig {
            shards: SHARDS,
            threads: 2,
            quality: q.clone(),
            factory: registry::factory(spec).unwrap(),
            path: path.clone(),
            spec: registry::canonical(spec).unwrap(),
            temporal: TemporalConfig::new(interval).unwrap(),
            dt: DT,
            max_retries: 0,
        },
    )
    .unwrap_or_else(|e| panic!("{tag}: stream pipeline failed: {e}"));
    (path, report)
}

fn assert_bits_eq(a: &Snapshot, b: &Snapshot, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for f in 0..6 {
        for i in 0..a.len() {
            assert_eq!(
                a.fields[f][i].to_bits(),
                b.fields[f][i].to_bits(),
                "{tag}: field {f} particle {i}"
            );
        }
    }
}

#[test]
fn chains_hold_the_bound_at_every_timestep() {
    // 16 steps so interval 16 exercises a 15-deep delta chain: if
    // quantization error accumulated across deltas, the tail steps
    // would breach the bound.
    let n = 2_000;
    let steps = 16;
    let ts = series(n, steps);
    let ctx = ExecCtx::with_threads(2);
    for (qname, q) in [
        ("abs", Quality::abs(1e-2)),
        ("rel", Quality::rel(1e-4)),
        ("pw_rel", Quality::pw_rel(1e-3)),
    ] {
        for interval in [1usize, 4, 16] {
            for spec in ["sz_lv", "gzip"] {
                let tag = format!("{qname}/k={interval}/{spec}");
                let (path, report) = stream(&ts, spec, &q, interval, &tag);
                let reader = ShardReader::open(&path)
                    .unwrap_or_else(|e| panic!("{tag}: open: {e}"));
                reader.verify_file_crc().unwrap();
                let tc = reader.temporal().expect("stream archive has a chain");
                assert_eq!(tc.interval as usize, interval, "{tag}");
                assert_eq!(tc.steps.len(), steps, "{tag}");
                assert_eq!(report.steps.len(), steps, "{tag}");
                for t in 0..steps {
                    assert_eq!(
                        tc.steps[t].keyframe,
                        t % interval == 0,
                        "{tag}: step {t} keyframe cadence"
                    );
                    let dec = reader
                        .decode_timestep(t, &ctx)
                        .unwrap_or_else(|e| panic!("{tag}: decode step {t}: {e}"));
                    // O(K) seek: exactly the keyframe group's shards
                    // from the keyframe through t, never the archive.
                    let group = reader.shards_for_timestep(t).unwrap();
                    assert_eq!(dec.shards_touched, group.len(), "{tag}: step {t}");
                    assert_eq!(
                        group.len(),
                        (t - dec.keyframe + 1) * SHARDS,
                        "{tag}: step {t} group size"
                    );
                    assert_eq!(dec.keyframe, t - t % interval, "{tag}: step {t}");
                    assert_eq!(dec.particle_start, (t * n) as u64, "{tag}");
                    assert_eq!(dec.particle_end, ((t + 1) * n) as u64, "{tag}");
                    // The headline guarantee: within the typed bound at
                    // every chain depth.
                    verify_quality(&ts[t], &dec.snapshot, &q)
                        .unwrap_or_else(|e| panic!("{tag}: step {t} drifted: {e}"));
                }
                assert!(reader.decode_timestep(steps, &ctx).is_err(), "{tag}");
                std::fs::remove_file(&path).ok();
            }
        }
    }
}

#[test]
fn mid_chain_seek_matches_sequential_replay() {
    // Replay the whole chain step by step through the *public* stored
    // representation (slab decodes + predictor), independently of
    // decode_timestep's internal seek, and demand bitwise equality —
    // the mid-chain O(K) seek must be a pure optimization.
    let n = 2_000;
    let steps = 8;
    let ts = series(n, steps);
    let q = Quality::rel(1e-4);
    let (path, _) = stream(&ts, "sz_lv", &q, 4, "seq_replay");
    let reader = ShardReader::open(&path).unwrap();
    let tc = reader.temporal().unwrap().clone();
    let ctx = ExecCtx::with_threads(2);
    let seq = ExecCtx::sequential();

    let slab = |t: usize, ctx: &ExecCtx| -> Snapshot {
        decode_shards(
            &reader,
            reader.spec(),
            Some(((t * n) as u64, ((t + 1) * n) as u64)),
            ctx,
        )
        .unwrap()
        .snapshot
    };
    let mut cur: Option<Snapshot> = None;
    for t in 0..steps {
        let step = &tc.steps[t];
        let raw = slab(t, &ctx);
        cur = Some(if step.keyframe {
            raw
        } else {
            let pred = predict(cur.as_ref().unwrap(), step.dt);
            reconstruct(&pred, &raw, &step.bounds).unwrap()
        });
        let dec = reader.decode_timestep(t, &ctx).unwrap();
        assert_bits_eq(
            cur.as_ref().unwrap(),
            &dec.snapshot,
            &format!("seek vs sequential replay at step {t}"),
        );
        // Thread count must not change a single bit either.
        let dec1 = reader.decode_timestep(t, &seq).unwrap();
        assert_bits_eq(
            &dec.snapshot,
            &dec1.snapshot,
            &format!("thread-count determinism at step {t}"),
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn delta_steps_beat_keyframes_on_coherent_streams() {
    // The point of the delta path: velocity extrapolation leaves small
    // residuals on leapfrog cosmology data, so delta steps must come
    // out materially smaller than keyframes (acceptance floor 1.5x).
    let ts = series(4_000, 8);
    let (path, report) = stream(&ts, "sz_lv", &Quality::rel(1e-4), 4, "ratio");
    let ratio = report
        .delta_vs_keyframe()
        .expect("interval 4 over 8 steps has both kinds");
    assert!(
        ratio >= 1.5,
        "delta steps only {ratio:.2}x smaller than keyframes"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn reordering_codecs_are_rejected_end_to_end() {
    let ts = series(1_000, 4);
    let path = std::env::temp_dir().join(format!(
        "nblc_temporal_rt_{}_reorder.nblc",
        std::process::id()
    ));
    // Write side: the stream pipeline refuses to start.
    let err = run_insitu_stream(
        &ts,
        &StreamConfig {
            shards: SHARDS,
            threads: 1,
            quality: Quality::rel(1e-4),
            factory: registry::factory("sz_cpc2000").unwrap(),
            path: path.clone(),
            spec: registry::canonical("sz_cpc2000").unwrap(),
            temporal: TemporalConfig::new(2).unwrap(),
            dt: DT,
            max_retries: 0,
        },
    )
    .expect_err("reordering codec must be rejected at stream-write time");
    assert!(
        err.to_string().contains("order-preserving"),
        "unexpected error: {err}"
    );

    // Decode side: a temporal archive whose spec reorders (built by
    // driving the writer directly — the pipeline refuses) must be
    // rejected at decode_timestep, since residual replay would pair
    // residuals with the wrong particles.
    let spec = registry::canonical("sz_cpc2000").unwrap();
    let q = Quality::rel(1e-4);
    let comp = registry::build_str(&spec).unwrap();
    let mut w = ShardWriter::create_stream(&path, &spec, &q).unwrap();
    w.enable_temporal(2).unwrap();
    for (t, snap) in ts.iter().enumerate() {
        w.begin_timestep(t % 2 == 0, DT, [1e-3; 6]).unwrap();
        let b = comp.compress(snap, &q).unwrap();
        w.write_shard(t * snap.len(), (t + 1) * snap.len(), &b, 0)
            .unwrap();
    }
    w.finish().unwrap();
    let reader = ShardReader::open(&path).unwrap();
    assert!(reader.temporal().is_some());
    let err = reader
        .decode_timestep(0, &ExecCtx::sequential())
        .expect_err("reordering codec must be rejected at decode time");
    assert!(
        err.to_string().contains("reordering"),
        "unexpected error: {err}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_stream_archives_fail_typed() {
    // The footer now ends with the temporal chain; any cut through it
    // must surface as a typed error through the normal open path (the
    // dense hostile sweep lives in the archive unit tests).
    let ts = series(500, 4);
    let (path, _) = stream(&ts, "sz_lv", &Quality::rel(1e-4), 2, "trunc");
    let bytes = std::fs::read(&path).unwrap();
    let foot_len =
        u64::from_le_bytes(bytes[bytes.len() - 16..bytes.len() - 8].try_into().unwrap());
    let data_end = bytes.len() - 16 - foot_len as usize;
    for cut in (data_end..bytes.len()).step_by(7).chain([bytes.len() - 1]) {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(ShardReader::open(&path).is_err(), "cut at {cut}");
    }
    std::fs::remove_file(&path).ok();
}
