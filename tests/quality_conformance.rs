//! Max-error conformance sweep for the typed quality targets: `Abs`,
//! `Rel`, and `PwRel` guarantees must hold per field (pointwise for
//! `PwRel`) across the full codec lineup, modulo the reordering codecs'
//! deterministic permutation; `Lossless` must be bit-exact on the
//! per-field codecs and a typed error on the joint/reordering ones.

use nblc::compressors::{full_lineup, registry};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::exec::ExecCtx;
use nblc::quality::{verify_quality, ErrorBound, Quality};
use nblc::snapshot::Snapshot;

const N: usize = 3_000;

fn md() -> Snapshot {
    generate_md(&MdConfig {
        n_particles: N,
        ..Default::default()
    })
}

/// The same snapshot shifted away from zero: every field strictly
/// positive, so `pw_rel` resolves to a usable uniform bound (zero
/// crossings would degrade it to exact coding, which the reordering
/// codecs reject — covered separately below).
fn md_positive() -> Snapshot {
    let s = md();
    let fields: [Vec<f32>; 6] =
        std::array::from_fn(|f| s.fields[f].iter().map(|&x| x + 64.0).collect());
    Snapshot::new("md+64", fields, s.box_size).unwrap()
}

fn sweep(snap: &Snapshot, quality: &Quality, tag: &str) {
    let ctx = ExecCtx::sequential();
    for name in full_lineup() {
        if name == "fpzip" {
            // Precision-based: lands near the bound, not strictly under
            // it (paper §IV) — excluded from bound assertions everywhere.
            continue;
        }
        let c = format!("{tag}/{name}");
        let comp = registry::build_str(name).unwrap();
        let bundle = comp
            .compress(snap, quality)
            .unwrap_or_else(|e| panic!("{c}: compress failed: {e}"));
        let recon = comp
            .decompress(&bundle)
            .unwrap_or_else(|e| panic!("{c}: decompress failed: {e}"));
        assert_eq!(recon.len(), snap.len(), "{c}");
        let reference = match registry::sort_permutation_quality(name, snap, quality, &ctx)
            .unwrap_or_else(|e| panic!("{c}: sort permutation failed: {e}"))
        {
            Some(perm) => snap.permute(&perm).unwrap(),
            None => snap.clone(),
        };
        verify_quality(&reference, &recon, quality)
            .unwrap_or_else(|e| panic!("{c}: quality violated: {e}"));
        // The archived metadata agrees with what was enforced.
        let bounds = bundle.field_bounds.unwrap_or_else(|| panic!("{c}: bounds missing"));
        assert!(bounds.iter().all(|&b| b > 0.0), "{c}: lossy sweep resolves positive bounds");
    }
}

#[test]
fn rel_bounds_hold_across_lineup() {
    sweep(&md(), &Quality::rel(1e-3), "rel");
    sweep(&md(), &Quality::rel(1e-5), "rel-tight");
}

#[test]
fn abs_bounds_hold_across_lineup() {
    // 2e-3 absolute sits comfortably inside CPC2000's 21-bit Morton
    // grid on MD-scale ranges while still being a meaningful target.
    sweep(&md(), &Quality::abs(2e-3), "abs");
}

#[test]
fn pw_rel_bounds_hold_across_lineup() {
    sweep(&md_positive(), &Quality::pw_rel(1e-3), "pw_rel");
}

#[test]
fn per_field_overrides_hold_across_lineup() {
    // The motivating case: tighter positions than velocities.
    let q = Quality::rel(1e-3).with_coords(ErrorBound::Rel(1e-5));
    sweep(&md(), &q, "mixed");
    // And a mixed-kind target.
    let q = Quality::abs(2e-3)
        .with_velocities(ErrorBound::Rel(1e-4));
    sweep(&md(), &q, "mixed-kind");
}

#[test]
fn lossless_policy_across_lineup() {
    let snap = md();
    let q = Quality::lossless();
    for name in full_lineup() {
        let comp = registry::build_str(name).unwrap();
        let result = comp.compress(&snap, &q);
        if comp.reorders() {
            // Joint codecs cannot reconstruct exactly: typed rejection.
            let err = result.err().unwrap_or_else(|| panic!("{name} must reject lossless"));
            assert!(err.to_string().contains("lossless"), "{name}: {err}");
        } else {
            // Per-field codecs route through the exact fallback.
            let bundle = result.unwrap_or_else(|e| panic!("{name}: {e}"));
            let recon = comp.decompress(&bundle).unwrap();
            for f in 0..6 {
                let a: Vec<u32> = snap.fields[f].iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = recon.fields[f].iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "{name} field {f} must round-trip bit-exactly");
            }
        }
    }
}

#[test]
fn pw_rel_with_zero_crossings_degrades_to_exact_on_per_field_codecs() {
    let snap = md(); // velocities cross zero
    let q = Quality::pw_rel(1e-3);
    let comp = registry::build_str("sz_lv").unwrap();
    let bundle = comp.compress(&snap, &q).unwrap();
    let recon = comp.decompress(&bundle).unwrap();
    verify_quality(&snap, &recon, &q).unwrap();
    // ...while a reordering codec reports the typed error instead of
    // silently violating the pointwise guarantee.
    let joint = registry::build_str("sz_lv_prx").unwrap();
    let min_abs_is_zeroish = snap.fields[3..]
        .iter()
        .any(|f| f.iter().fold(f64::INFINITY, |m, &x| m.min((x as f64).abs())) < 1e-10);
    if min_abs_is_zeroish {
        assert!(joint.compress(&snap, &q).is_err());
    }
}
