//! The parallel engine's central contract: for EVERY registry codec and
//! every thread budget, compressed output is byte-identical to the
//! sequential output, and parallel decompression round-trips within the
//! error bound (modulo the reordering codecs' deterministic
//! permutation). Archives must never depend on how many threads
//! produced them.

use nblc::compressors::{full_lineup, registry};
use nblc::coordinator::pipeline::{run_insitu, InsituConfig, Sink};
use nblc::data::archive::{decode_shards, ShardReader};
use nblc::data::gen_cosmo::{generate_cosmo, CosmoConfig};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::exec::ExecCtx;
use nblc::kernels::Kernels;
use nblc::quality::Quality;
use nblc::snapshot::{verify_bounds, Snapshot};

const THREADS: [usize; 3] = [1, 2, 8];

fn assert_deterministic(spec: &str, snap: &Snapshot, eb_rel: f64) {
    let comp = registry::build_str(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
    let quality = Quality::rel(eb_rel);
    let seq = comp
        .compress(snap, &quality)
        .unwrap_or_else(|e| panic!("{spec}: sequential compress failed: {e}"));
    for threads in THREADS {
        let ctx = ExecCtx::with_threads(threads);
        let par = comp
            .compress_with(&ctx, snap, &quality)
            .unwrap_or_else(|e| panic!("{spec}@{threads}: compress failed: {e}"));
        assert_eq!(
            seq.fields.len(),
            par.fields.len(),
            "{spec}@{threads}: stream count"
        );
        for (fi, (a, b)) in seq.fields.iter().zip(par.fields.iter()).enumerate() {
            assert_eq!(a.name, b.name, "{spec}@{threads}: field {fi} name");
            assert_eq!(
                a.bytes, b.bytes,
                "{spec}@{threads}: field '{}' bytes differ from sequential",
                a.name
            );
        }

        // Round-trip through the parallel decoder and verify the bound.
        let recon = comp
            .decompress_with(&ctx, &par)
            .unwrap_or_else(|e| panic!("{spec}@{threads}: decompress failed: {e}"));
        assert_eq!(recon.len(), snap.len(), "{spec}@{threads}: particle count");
        if spec == "fpzip" {
            // Precision-based: lands *near* the requested bound, not
            // strictly under it (paper §IV) — length check only.
            continue;
        }
        let reference = match registry::sort_permutation_with(spec, snap, eb_rel, &ctx).unwrap() {
            Some(perm) => snap.permute(&perm).unwrap(),
            None => snap.clone(),
        };
        verify_bounds(&reference, &recon, eb_rel)
            .unwrap_or_else(|e| panic!("{spec}@{threads}: bound violated: {e}"));
    }

    // Kernel backends must not change bytes either (the full SIMD
    // matrix lives in backend_equivalence.rs; this crosses it with the
    // engine's thread sweep on a parallel budget).
    for kern in Kernels::variants() {
        let ctx = ExecCtx::with_threads(2).with_kernels(kern);
        let out = comp
            .compress_with(&ctx, snap, &quality)
            .unwrap_or_else(|e| panic!("{spec}@{}: compress failed: {e}", kern.label));
        for (a, b) in seq.fields.iter().zip(out.fields.iter()) {
            assert_eq!(
                a.bytes, b.bytes,
                "{spec}@{}: field '{}' bytes depend on the kernel backend",
                kern.label, a.name
            );
        }
    }
}

#[test]
fn full_lineup_is_byte_identical_across_thread_counts() {
    let md = generate_md(&MdConfig {
        n_particles: 4_000,
        ..Default::default()
    });
    for spec in full_lineup() {
        assert_deterministic(spec, &md, 1e-4);
    }
}

#[test]
fn tuned_specs_and_modes_are_byte_identical_across_thread_counts() {
    let md = generate_md(&MdConfig {
        n_particles: 4_000,
        ..Default::default()
    });
    for spec in [
        // Non-default segment/ignore parameters exercise the parallel
        // segmented sort at many segment boundaries.
        "sz_lv_rx:segment=256",
        "sz_lv_prx:segment=1024,ignore=4",
        "sz_lv_rx:source=velocities",
        "sz:pred=lv,lossless=true",
        "mode:best_speed",
        "mode:best_tradeoff",
        "mode:best_compression",
    ] {
        assert_deterministic(spec, &md, 1e-4);
    }
}

#[test]
fn cosmology_data_is_byte_identical_across_thread_counts() {
    // The orderly-coordinate dataset stresses different code/escape
    // distributions than MD.
    let cosmo = generate_cosmo(&CosmoConfig {
        n_particles: 3_000,
        ..Default::default()
    });
    for spec in ["sz_lv", "sz_lv_rx", "sz_cpc2000"] {
        assert_deterministic(spec, &cosmo, 1e-3);
    }
}

#[test]
fn pipeline_archives_decode_identically_at_any_concurrency() {
    // The v3 sink appends shard records in worker-completion order, so
    // the FILE bytes may differ across worker/thread counts — but the
    // footer's logical shard order, every shard's compressed payload,
    // and the decoded snapshot must be bit-identical.
    let md = generate_md(&MdConfig {
        n_particles: 12_000,
        ..Default::default()
    });
    for name in ["sz_lv", "sz_lv_rx"] {
        let spec = registry::canonical(name).unwrap();
        let mut baseline: Option<(Vec<(u64, u64, u64)>, Vec<Vec<u8>>, Vec<Vec<u32>>)> = None;
        for (workers, threads) in [(1usize, 1usize), (2, 2), (4, 1)] {
            let path = std::env::temp_dir().join(format!(
                "nblc_det_{}_{name}_{workers}_{threads}.nblc",
                std::process::id()
            ));
            run_insitu(
                &md,
                &InsituConfig {
                    shards: 5,
                    layout: None,
                    workers,
                    threads,
                    queue_depth: 3,
                    quality: Quality::rel(1e-4),
                    factory: registry::factory(&spec).unwrap(),
                    sink: Sink::Archive {
                        path: path.clone(),
                        spec: spec.clone(),
                    },
                    spatial: None,
                    max_retries: 0,
                    sink_fault: None,
                },
            )
            .unwrap_or_else(|e| panic!("{name}@{workers}w/{threads}t: pipeline failed: {e}"));
            let reader = ShardReader::open(&path).unwrap();
            let order: Vec<(u64, u64, u64)> = reader
                .index()
                .entries
                .iter()
                .map(|e| (e.start, e.end, e.bytes_out))
                .collect();
            let payloads: Vec<Vec<u8>> = (0..reader.index().entries.len())
                .map(|i| {
                    let bundle = reader.read_shard(i).unwrap();
                    bundle.fields.iter().flat_map(|f| f.bytes.clone()).collect()
                })
                .collect();
            let dec = decode_shards(
                &reader,
                reader.spec(),
                None,
                &ExecCtx::with_threads(threads.max(workers)),
            )
            .unwrap();
            std::fs::remove_file(&path).ok();
            let bits: Vec<Vec<u32>> = dec
                .snapshot
                .fields
                .iter()
                .map(|f| f.iter().map(|x| x.to_bits()).collect())
                .collect();
            match &baseline {
                None => baseline = Some((order, payloads, bits)),
                Some((o0, p0, b0)) => {
                    assert_eq!(o0, &order, "{name}@{workers}w/{threads}t: logical shard order");
                    assert_eq!(p0, &payloads, "{name}@{workers}w/{threads}t: shard payload bytes");
                    assert_eq!(b0, &bits, "{name}@{workers}w/{threads}t: decoded snapshot bits");
                }
            }
        }
    }
}

#[test]
fn spatial_pipeline_archives_are_concurrency_invariant_and_cost_archives_spatial_free() {
    // Two pins in one: (1) a spatial-layout pipeline run produces the
    // same footer spatial block, shard payloads, and decoded bits at
    // every worker/thread combination; (2) a cost-layout run writes NO
    // spatial block — the non-spatial archive bytes are exactly the
    // pre-spatial format, so PR-over-PR file identity holds for
    // everyone not opting in.
    use nblc::coordinator::pipeline::SpatialInsitu;
    use nblc::coordinator::spatial::plan_spatial;
    use std::sync::Arc;

    let md = generate_md(&MdConfig {
        n_particles: 12_000,
        ..Default::default()
    });
    let spec = registry::canonical("sz_lv").unwrap();
    let plan = plan_spatial(&md, 5, 10, &ExecCtx::sequential()).unwrap();
    let mut baseline: Option<(Vec<u8>, Vec<Vec<u32>>)> = None;
    for (workers, threads) in [(1usize, 1usize), (2, 2), (4, 1)] {
        let path = std::env::temp_dir().join(format!(
            "nblc_det_spatial_{workers}_{threads}_{}.nblc",
            std::process::id()
        ));
        run_insitu(
            &plan.snapshot,
            &InsituConfig {
                shards: 5,
                layout: Some(plan.layout.clone()),
                workers,
                threads,
                queue_depth: 3,
                quality: Quality::rel(1e-4),
                factory: registry::factory(&spec).unwrap(),
                sink: Sink::Archive {
                    path: path.clone(),
                    spec: spec.clone(),
                },
                spatial: Some(SpatialInsitu {
                    bits: plan.bits,
                    seg: 2_048,
                    keys: Arc::clone(&plan.keys),
                }),
                max_retries: 0,
                sink_fault: None,
            },
        )
        .unwrap_or_else(|e| panic!("spatial@{workers}w/{threads}t: pipeline failed: {e}"));
        let reader = ShardReader::open(&path).unwrap();
        let sp = reader.spatial().expect("spatial block must be written").clone();
        // Serialize the block into a comparable byte stream.
        let mut blob = Vec::new();
        blob.extend_from_slice(&sp.bits.to_le_bytes());
        blob.extend_from_slice(&sp.seg.to_le_bytes());
        for s in &sp.shards {
            blob.extend_from_slice(&s.mkey_lo.to_le_bytes());
            blob.extend_from_slice(&s.mkey_hi.to_le_bytes());
            for v in s.bbox.iter().chain(s.seg_boxes.iter().flatten()) {
                blob.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        let dec = decode_shards(&reader, reader.spec(), None, &ExecCtx::with_threads(2)).unwrap();
        std::fs::remove_file(&path).ok();
        let bits: Vec<Vec<u32>> = dec
            .snapshot
            .fields
            .iter()
            .map(|f| f.iter().map(|x| x.to_bits()).collect())
            .collect();
        match &baseline {
            None => baseline = Some((blob, bits)),
            Some((b0, d0)) => {
                assert_eq!(b0, &blob, "@{workers}w/{threads}t: spatial block differs");
                assert_eq!(d0, &bits, "@{workers}w/{threads}t: decoded bits differ");
            }
        }
    }

    // Cost layout: `spatial: None` must leave the file spatial-free.
    let path = std::env::temp_dir().join(format!(
        "nblc_det_nonspatial_{}.nblc",
        std::process::id()
    ));
    run_insitu(
        &md,
        &InsituConfig {
            shards: 5,
            layout: None,
            workers: 2,
            threads: 1,
            queue_depth: 3,
            quality: Quality::rel(1e-4),
            factory: registry::factory(&spec).unwrap(),
            sink: Sink::Archive {
                path: path.clone(),
                spec: spec.clone(),
            },
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    let reader = ShardReader::open(&path).unwrap();
    assert!(
        reader.spatial().is_none(),
        "cost-layout archives must not grow a spatial block"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn permutation_is_thread_count_invariant() {
    let md = generate_md(&MdConfig {
        n_particles: 10_000,
        ..Default::default()
    });
    for spec in ["sz_lv_rx:segment=512", "sz_lv_prx", "cpc2000"] {
        let seq = registry::sort_permutation(spec, &md, 1e-4).unwrap().unwrap();
        for threads in THREADS {
            let ctx = ExecCtx::with_threads(threads);
            let par = registry::sort_permutation_with(spec, &md, 1e-4, &ctx)
                .unwrap()
                .unwrap();
            assert_eq!(seq, par, "{spec}@{threads}");
        }
    }
}
