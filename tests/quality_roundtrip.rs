//! Property tests for the typed quality layer: `ErrorBound`/`Quality`
//! parse → canonicalize → re-parse round-trips (canonical form is a
//! fixed point and resolution is preserved). The bare-`f64` spelling and
//! the `compress_rel` trait shims were removed in 0.7; this suite pins
//! the rejection path and the surviving `[pipeline] eb_rel` config alias.

use nblc::compressors::registry;
use nblc::config::{ConfigDoc, PipelineSettings};
use nblc::data::gen_md::{generate_md, MdConfig};
use nblc::quality::{ErrorBound, FieldStats, Quality};
use nblc::snapshot::FIELD_NAMES;
use nblc::testkit::{gen_field_like, Prop};
use nblc::util::rng::Pcg64;

fn gen_coeff(rng: &mut Pcg64, max_exp: u64) -> f64 {
    // mantissa in {1, 1.25, 3.7, 9.5} × 10^-(1..=max_exp): inside every
    // bound kind's accepted domain and stable under {:e} round-trips.
    let m = [1.0, 1.25, 3.7, 9.5][rng.below(4) as usize];
    let e = 1 + rng.below(max_exp);
    m * 10f64.powi(-(e as i32))
}

fn gen_bound(rng: &mut Pcg64) -> ErrorBound {
    match rng.below(4) {
        0 => ErrorBound::Abs(gen_coeff(rng, 8)),
        1 => ErrorBound::Rel(gen_coeff(rng, 12)),
        2 => ErrorBound::PwRel(gen_coeff(rng, 12)),
        _ => ErrorBound::Lossless,
    }
}

#[test]
fn error_bound_canonical_is_a_parse_fixed_point() {
    Prop::new("ErrorBound canonical round-trip").cases(200).run(|rng| {
        let b = gen_bound(rng);
        let c = b.canonical();
        let reparsed = ErrorBound::parse(&c).unwrap_or_else(|e| panic!("{c}: {e}"));
        assert_eq!(reparsed, b, "{c}");
        assert_eq!(reparsed.canonical(), c, "{c} must be a fixed point");
        // Resolution (the semantics) survives the round-trip on
        // arbitrary field stats.
        let xs = gen_field_like(rng, 1..500);
        let st = FieldStats::scan(&xs);
        assert_eq!(b.resolve(&st).to_bits(), reparsed.resolve(&st).to_bits(), "{c}");
    });
}

#[test]
fn quality_canonical_is_a_parse_fixed_point() {
    Prop::new("Quality canonical round-trip").cases(200).run(|rng| {
        let mut q = Quality::new(gen_bound(rng));
        // Up to 3 distinct per-field overrides.
        for _ in 0..rng.below(4) {
            let field = FIELD_NAMES[rng.below(6) as usize];
            q = q.clone().with(field, gen_bound(rng)).unwrap();
        }
        let c = q.canonical();
        let reparsed = Quality::parse(&c).unwrap_or_else(|e| panic!("{c}: {e}"));
        assert_eq!(reparsed.canonical(), c, "{c} must be a fixed point");
        let xs: [Vec<f32>; 6] = std::array::from_fn(|_| gen_field_like(rng, 1..300));
        let stats: [FieldStats; 6] = std::array::from_fn(|f| FieldStats::scan(&xs[f]));
        let a = q.resolve_fields(&stats);
        let b = reparsed.resolve_fields(&stats);
        for f in 0..6 {
            assert_eq!(a[f].to_bits(), b[f].to_bits(), "{c} field {f}");
        }
    });
}

#[test]
fn bare_f64_spellings_are_rejected() {
    // The legacy value-range-relative bare-float spelling was removed in
    // 0.7: a bound must name its kind everywhere a string is parsed.
    assert!(ErrorBound::parse("1e-4").is_err());
    assert!(ErrorBound::parse("0.001").is_err());
    assert!(Quality::parse("1e-4").is_err());
    let doc = ConfigDoc::parse("[pipeline]\nquality = \"1e-3\"\n").unwrap();
    assert!(PipelineSettings::from_doc(&doc).is_err());
    // The deprecated [pipeline] eb_rel *float key* survives (it is typed
    // by the key name, not a bare string) and still aliases uniform rel.
    let doc = ConfigDoc::parse("[pipeline]\neb_rel = 1e-3\n").unwrap();
    let s = PipelineSettings::from_doc(&doc).unwrap();
    assert_eq!(s.quality, Quality::rel(1e-3));
}

#[test]
fn sequential_and_ctx_compress_are_byte_identical() {
    // compress() is a thin sequential wrapper over compress_with(); the
    // two entry points must produce identical archives (this pin used to
    // cover the removed compress_rel shims as well).
    let snap = generate_md(&MdConfig {
        n_particles: 3_000,
        ..Default::default()
    });
    let q = Quality::rel(1e-4);
    for name in ["sz_lv", "sz_lv_rx", "cpc2000", "gzip"] {
        let comp = registry::build_str(name).unwrap();
        let typed = comp.compress(&snap, &q).unwrap();
        let ctx = comp
            .compress_with(&nblc::exec::ExecCtx::sequential(), &snap, &q)
            .unwrap();
        assert_eq!(typed.fields.len(), ctx.fields.len(), "{name}");
        for (a, b) in typed.fields.iter().zip(ctx.fields.iter()) {
            assert_eq!(a.bytes, b.bytes, "{name}");
        }
        assert_eq!(typed.eb_rel, 1e-4, "{name}: legacy header field");
    }
}

#[test]
fn spec_eb_hint_and_archive_quality_agree() {
    // The registry's eb= hint feeds the driver's default quality, the
    // canonical spec stays hint-free, and what the archive records is
    // the canonical quality string.
    let hint = registry::quality_hint("sz_lv:eb=pw_rel:1e-3").unwrap().unwrap();
    assert_eq!(hint, ErrorBound::PwRel(1e-3));
    let q = Quality::new(hint);
    assert_eq!(q.canonical(), "pw_rel:1e-3");
    assert_eq!(
        registry::canonical("sz_lv:eb=pw_rel:1e-3").unwrap(),
        registry::canonical("sz_lv").unwrap()
    );
}
