//! Fig. 1 — per-variable compression ratios of SZ-LCF vs SZ-LV on (a)
//! HACC and (b) AMDF under eb_rel = 1e-4 (paper: SZ-LV higher on every
//! variable, +10.1% on average).

use nblc::bench::{f2, pct, Table, EB_REL};
use nblc::compressors::sz::Sz;
use nblc::data::DatasetKind;
use nblc::snapshot::{FieldCompressor, FIELD_NAMES};
use nblc::util::stats::value_range;

fn main() {
    let mut t = Table::new(
        "Fig. 1: SZ-LCF vs SZ-LV per-variable ratios @ eb_rel=1e-4",
        &["Dataset", "Field", "SZ-LCF", "SZ-LV", "gain"],
    );
    let mut total_gain = 0f64;
    let mut count = 0usize;
    for kind in [DatasetKind::Hacc, DatasetKind::Amdf] {
        let s = nblc::bench::bench_snapshot(kind);
        for f in 0..6 {
            let eb = value_range(&s.fields[f]) * EB_REL;
            let lcf_bytes = Sz::lcf().compress(&s.fields[f], eb).unwrap().len();
            let lv_bytes = Sz::lv().compress(&s.fields[f], eb).unwrap().len();
            let orig = s.fields[f].len() * 4;
            let r_lcf = orig as f64 / lcf_bytes as f64;
            let r_lv = orig as f64 / lv_bytes as f64;
            let gain = r_lv / r_lcf - 1.0;
            total_gain += gain;
            count += 1;
            t.row(vec![
                kind.name().into(),
                FIELD_NAMES[f].into(),
                f2(r_lcf),
                f2(r_lv),
                pct(gain),
            ]);
            assert!(r_lv > r_lcf, "SZ-LV must beat SZ-LCF on every variable");
        }
    }
    t.print();
    t.write_csv("fig1_szlv").unwrap();
    println!(
        "\nmean SZ-LV ratio gain: {} (paper: +10.1% average)",
        pct(total_gain / count as f64)
    );
}
