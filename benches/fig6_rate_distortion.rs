//! Fig. 6 — rate-distortion (PSNR vs bits/value) of ZFP, FPZIP,
//! CPC2000, SZ-LV and SZ-CPC2000 on both data sets. FPZIP sweeps
//! retained bits; everything else sweeps the relative error bound.
//! Paper shape: SZ-CPC2000 best below 10 bits/value on both data sets
//! (i.e. at ratios above ~3.2); only bit-rates < 16 are reported.

use nblc::bench::{f1, f2, Table};
use nblc::compressors::cpc2000::Cpc2000;
use nblc::compressors::fpzip::Fpzip;
use nblc::compressors::sz::Sz;
use nblc::compressors::szcpc::SzCpc2000;
use nblc::compressors::zfp::Zfp;
use nblc::data::DatasetKind;
use nblc::metrics::ratedist::{rate_distortion_curve, standard_bounds};
use nblc::metrics::{ErrorStats, RdPoint};
use nblc::quality::Quality;
use nblc::snapshot::{PerField, Snapshot, SnapshotCompressor};

fn fpzip_curve(s: &Snapshot) -> Vec<RdPoint> {
    let mut out = Vec::new();
    for p in [10u32, 12, 14, 16, 18, 20, 24, 28] {
        let comp = PerField(Fpzip::with_retained(p));
        let Ok(bundle) = comp.compress(s, &Quality::rel(1e-4)) else { continue };
        let Ok(recon) = comp.decompress(&bundle) else { continue };
        let Ok(psnr) = ErrorStats::snapshot_psnr(s, &recon) else { continue };
        out.push(RdPoint {
            eb_rel: 0.0,
            bit_rate: bundle.bit_rate(),
            psnr,
            ratio: bundle.compression_ratio(),
        });
    }
    out
}

fn main() {
    let mut t = Table::new(
        "Fig. 6: rate-distortion (bit-rate < 16 bits/value)",
        &["Dataset", "Method", "eb_rel", "bits/value", "PSNR (dB)", "ratio"],
    );
    for kind in [DatasetKind::Hacc, DatasetKind::Amdf] {
        let s = nblc::bench::bench_snapshot(kind);
        let bounds = standard_bounds();

        let named: Vec<(&str, Vec<RdPoint>)> = vec![
            (
                "zfp",
                rate_distortion_curve(&s, &PerField(Zfp), &bounds, None),
            ),
            ("fpzip", fpzip_curve(&s)),
            (
                "cpc2000",
                rate_distortion_curve(
                    &s,
                    &Cpc2000,
                    &bounds,
                    Some(&|snap: &Snapshot, eb: f64| Cpc2000.sort_permutation(snap, eb)),
                ),
            ),
            (
                "sz_lv",
                rate_distortion_curve(&s, &PerField(Sz::lv()), &bounds, None),
            ),
            (
                "sz_cpc2000",
                rate_distortion_curve(
                    &s,
                    &SzCpc2000,
                    &bounds,
                    Some(&|snap: &Snapshot, eb: f64| SzCpc2000::default().sort_permutation(snap, eb)),
                ),
            ),
        ];
        for (name, points) in &named {
            for p in points {
                if p.bit_rate >= 16.0 {
                    continue;
                }
                t.row(vec![
                    kind.name().into(),
                    (*name).into(),
                    format!("{:.0e}", p.eb_rel),
                    f2(p.bit_rate),
                    f1(p.psnr),
                    f2(p.ratio),
                ]);
            }
        }

        // Shape check: in the low-rate regime (< 10 bits/value) the best
        // PSNR at comparable bit-rate belongs to SZ-CPC2000 on AMDF; on
        // HACC sz_lv-family leads. Compare PSNR at the closest bit-rates.
        let interp_at = |pts: &Vec<RdPoint>, rate: f64| -> Option<f64> {
            // nearest point below 10 bits
            pts.iter()
                .filter(|p| p.bit_rate < 10.0)
                .min_by(|a, b| {
                    (a.bit_rate - rate)
                        .abs()
                        .partial_cmp(&(b.bit_rate - rate).abs())
                        .unwrap()
                })
                .map(|p| p.psnr)
        };
        let get = |n: &str| named.iter().find(|(name, _)| *name == n).unwrap();
        if kind == DatasetKind::Amdf {
            let szcpc = interp_at(&get("sz_cpc2000").1, 8.0);
            let zfp = interp_at(&get("zfp").1, 8.0);
            if let (Some(a), Some(b)) = (szcpc, zfp) {
                assert!(
                    a > b,
                    "SZ-CPC2000 must dominate ZFP at low rate on AMDF: {a:.1} vs {b:.1}"
                );
            }
        }
    }
    t.print();
    t.write_csv("fig6_rate_distortion").unwrap();
}
