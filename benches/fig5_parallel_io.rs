//! Fig. 5 — parallel I/O study on HACC: time to write initial data vs
//! time to compress + write compressed data with ZFP, FPZIP and SZ-LV
//! at 16..1024 processes.
//!
//! Single-core compression rates and ratios are MEASURED on this
//! machine; the cluster write/scaling behaviour comes from the GPFS
//! model (substitution per DESIGN.md §2). Paper claims to reproduce in
//! shape: compression wins from 64 procs on; SZ-LV reduces I/O time by
//! ~80% at 1024 procs and beats the second-best method by ~60%.

use nblc::bench::{f1, f2, pct, Table, EB_REL};
use nblc::compressors::registry;
use nblc::coordinator::GpfsModel;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::util::timer::time_it;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let mb = s.total_bytes() as f64 / 1e6;

    // Measure single-core rate + ratio per compressor.
    let mut measured = Vec::new();
    for name in ["zfp", "fpzip", "sz_lv"] {
        let comp = registry::build_str(name).unwrap();
        let (bundle, secs) = time_it(|| comp.compress(&s, &Quality::rel(EB_REL)).unwrap());
        measured.push((name, mb * 1e6 / secs, bundle.compression_ratio()));
        println!(
            "measured {name}: {:.1} MB/s, ratio {:.2}",
            mb / secs,
            bundle.compression_ratio()
        );
    }

    // Per-process share of the paper-scale snapshot at P=1024.
    let model = GpfsModel::default();
    let bytes_per_proc: u64 = 1 << 30; // 1 GiB/process (weak scaling)
    let mut t = Table::new(
        "Fig. 5: write-initial vs compress+write (GPFS model, measured rates)",
        &["Procs", "Method", "T_initial (s)", "T_comp (s)", "T_write_comp (s)", "I/O reduction"],
    );
    let mut csv_rows = Vec::new();
    for procs in [16usize, 64, 128, 256, 512, 1024] {
        for &(name, rate, ratio) in &measured {
            let (t0, tc, twc) = model.insitu_times(bytes_per_proc, procs, rate, ratio);
            let reduction = 1.0 - (tc + twc) / t0;
            t.row(vec![
                format!("{procs}"),
                name.into(),
                f1(t0),
                f1(tc),
                f2(twc),
                pct(reduction),
            ]);
            csv_rows.push((procs, name, t0, tc, twc, reduction));
        }
    }
    t.print();
    t.write_csv("fig5_parallel_io").unwrap();

    // Shape checks.
    let at = |p: usize, n: &str| {
        csv_rows
            .iter()
            .find(|(pp, nn, ..)| *pp == p && *nn == n)
            .unwrap()
    };
    let (_, _, t0, tc, twc, red_sz) = at(1024, "sz_lv");
    println!("\nshape checks (paper Fig. 5):");
    println!(
        "  SZ-LV @1024: {:.0}s direct vs {:.0}s compressed ({} reduction; paper ~80%)",
        t0,
        tc + twc,
        pct(*red_sz)
    );
    assert!(*red_sz > 0.6, "SZ-LV must cut I/O time by well over 60% at 1024");
    // Compression must win from 64 procs on for SZ-LV and FPZIP. Our
    // ZFP implementation is slower than the authors' binary (53 vs
    // ~170 MB/s single-core), which pushes its crossover to ~512 procs
    // — recorded as deviation 5 in EXPERIMENTS.md.
    for procs in [64usize, 128, 256, 512, 1024] {
        for name in ["fpzip", "sz_lv"] {
            let (_, _, t0, tc, twc, _) = at(procs, name);
            assert!(tc + twc < *t0, "{name}@{procs}: compression must win");
        }
    }
    {
        let (_, _, t0, tc, twc, _) = at(1024, "zfp");
        assert!(tc + twc < *t0, "zfp@1024: compression must win at full scale");
    }
    // SZ-LV beats the second best.
    let best_other = ["zfp", "fpzip"]
        .iter()
        .map(|n| {
            let (_, _, _, tc, twc, _) = at(1024, n);
            tc + twc
        })
        .fold(f64::INFINITY, f64::min);
    let sz_time = tc + twc;
    println!(
        "  SZ-LV total {sz_time:.1}s vs second-best {best_other:.1}s ({} faster; paper ~60%)",
        pct(1.0 - sz_time / best_other)
    );
    assert!(sz_time < best_other, "SZ-LV must be the fastest end-to-end");
}
