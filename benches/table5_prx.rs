//! Table V — SZ-LV-PRX: partial-radix sorting with different numbers of
//! ignored trailing 3-bit groups (paper: ratio stays 3.20 up to 6
//! ignored groups while the rate climbs 35.0 -> 43.8 MB/s; at 8 groups
//! the ratio starts to slip).

use nblc::bench::{f1, f2, f3, Table, EB_REL};
use nblc::compressors::szrx::SzRx;
use nblc::compressors::sz::Sz;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::snapshot::{PerField, SnapshotCompressor};
use nblc::util::timer::time_it;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Amdf);
    let mb = s.total_bytes() as f64 / 1e6;
    let mut t = Table::new(
        &format!("Table V: SZ-LV-PRX ignored-bits sweep, segment 16384 (n={})", s.len()),
        &["Method", "Segment", "Ignored 3-bit groups", "Ratio", "Rate (MB/s)"],
    );
    let (plain, secs) = time_it(|| PerField(Sz::lv()).compress(&s, &Quality::rel(EB_REL)).unwrap());
    t.row(vec![
        "SZ-LV".into(),
        "/".into(),
        "/".into(),
        f2(plain.compression_ratio()),
        f1(mb / secs),
    ]);
    let mut full_rx_ratio = 0.0;
    for groups in [0u32, 2, 4, 6, 8] {
        let comp = SzRx {
            ignored_groups: groups,
            ..SzRx::rx(16384)
        };
        let (bundle, secs) = time_it(|| comp.compress(&s, &Quality::rel(EB_REL)).unwrap());
        let ratio = bundle.compression_ratio();
        if groups == 0 {
            full_rx_ratio = ratio;
        }
        t.row(vec![
            "SZ-LV-PRX".into(),
            "16384".into(),
            format!("{groups}"),
            f3(ratio),
            f1(mb / secs),
        ]);
        if groups <= 6 {
            assert!(
                (ratio - full_rx_ratio).abs() / full_rx_ratio < 0.03,
                "PRX<=6 must keep the full-RX ratio (paper Table V)"
            );
        }
    }
    t.print();
    t.write_csv("table5_prx").unwrap();
}
