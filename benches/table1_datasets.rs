//! Table I — description of the N-body data sets used in the
//! assessment (generator statistics at bench scale; the paper's HACC is
//! 147.3M particles / 1.8 TB over 500 snapshots — this testbed runs the
//! scaled single-snapshot equivalents, DESIGN.md §2).

use nblc::bench::{f2, f3, Table};
use nblc::data::DatasetKind;
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::snapshot::FIELD_NAMES;
use nblc::util::humansize;
use nblc::util::stats::{monotone_fraction, value_range};

fn main() {
    let mut t = Table::new(
        "Table I: data sets (bench scale; paper: HACC 147.3M/1.8TB, AMDF 2.8M/34GB)",
        &["Name", "# Particles", "Snapshot Size", "Box"],
    );
    let mut stats = Table::new(
        "Table I-b: per-field structure (drives every later result)",
        &["Dataset", "Field", "Range", "LV NRMSE", "Monotone frac"],
    );
    for kind in [DatasetKind::Hacc, DatasetKind::Amdf] {
        let s = nblc::bench::bench_snapshot(kind);
        t.row(vec![
            kind.name().into(),
            format!("{}", s.len()),
            humansize::bytes(s.total_bytes() as u64),
            f2(s.box_size),
        ]);
        for f in 0..6 {
            stats.row(vec![
                kind.name().into(),
                FIELD_NAMES[f].into(),
                f2(value_range(&s.fields[f])),
                f3(LatticeQuantizer::prediction_nrmse(
                    &s.fields[f],
                    Predictor::LastValue,
                )),
                f3(monotone_fraction(&s.fields[f])),
            ]);
        }
    }
    t.print();
    stats.print();
    t.write_csv("table1_datasets").unwrap();
    stats.write_csv("table1_fields").unwrap();
}
