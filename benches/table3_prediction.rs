//! Table III — NRMSE of the LCF vs LV prediction models per variable
//! (paper: LV beats LCF on every variable of both data sets; coords
//! xx < yy << zz on HACC; everything ~0.06-0.25 on AMDF).

use nblc::bench::Table;
use nblc::data::DatasetKind;
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::snapshot::FIELD_NAMES;

fn main() {
    // Paper values for the reference columns.
    let paper_hacc = [(0.001, 0.0007), (0.003, 0.002), (0.061, 0.043),
                      (0.030, 0.018), (0.032, 0.020), (0.031, 0.019)];
    let paper_amdf = [(0.10, 0.07), (0.10, 0.06), (0.14, 0.09),
                      (0.24, 0.14), (0.25, 0.14), (0.24, 0.14)];
    let mut t = Table::new(
        "Table III: prediction NRMSE, LCF vs LV (paper values alongside)",
        &["Dataset", "Field", "LCF", "LV", "LCF(paper)", "LV(paper)"],
    );
    for (kind, paper) in [
        (DatasetKind::Hacc, &paper_hacc),
        (DatasetKind::Amdf, &paper_amdf),
    ] {
        let s = nblc::bench::bench_snapshot(kind);
        for f in 0..6 {
            let lcf = LatticeQuantizer::prediction_nrmse(&s.fields[f], Predictor::LinearCurveFit);
            let lv = LatticeQuantizer::prediction_nrmse(&s.fields[f], Predictor::LastValue);
            t.row(vec![
                kind.name().into(),
                FIELD_NAMES[f].into(),
                format!("{lcf:.4}"),
                format!("{lv:.4}"),
                format!("{:.4}", paper[f].0),
                format!("{:.4}", paper[f].1),
            ]);
            assert!(lv < lcf, "LV must beat LCF on {} {}", kind.name(), FIELD_NAMES[f]);
        }
    }
    t.print();
    t.write_csv("table3_prediction").unwrap();
    println!("\nshape check: LV < LCF on all 12 variables OK");
}
