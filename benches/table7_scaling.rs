//! Table VII — compression rate (GB/s) and parallel efficiency of ZFP,
//! FPZIP and SZ-LV on HACC at 1..1024 processes (measured single-core
//! rates + GPFS/straggler model; efficiency normalised to 16 procs as
//! in the paper; paper shape: ~99% to 256, ~84-88% at 1024).

use nblc::bench::{f2, pct, Table, EB_REL};
use nblc::compressors::registry;
use nblc::coordinator::GpfsModel;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::util::timer::time_it;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let mb = s.total_bytes() as f64 / 1e6;
    let mut measured = Vec::new();
    for name in ["zfp", "fpzip", "sz_lv"] {
        let comp = registry::build_str(name).unwrap();
        let (_, secs) = time_it(|| comp.compress(&s, &Quality::rel(EB_REL)).unwrap());
        measured.push((name, mb * 1e6 / secs));
    }

    let model = GpfsModel::default();
    let bytes_per_proc: u64 = 1 << 30;
    let mut t = Table::new(
        "Table VII: aggregate compression rate (GB/s) and parallel efficiency",
        &[
            "Procs", "ZFP GB/s", "ZFP eff", "FPZIP GB/s", "FPZIP eff", "SZ-LV GB/s",
            "SZ-LV eff",
        ],
    );
    for procs in [1usize, 16, 32, 64, 128, 256, 512, 1024] {
        let mut cells = vec![format!("{procs}")];
        for &(_, rate) in &measured {
            let agg = model.aggregate_rate(bytes_per_proc, rate, procs) / 1e9;
            let eff = model.efficiency(bytes_per_proc, rate, procs);
            cells.push(f2(agg));
            cells.push(if procs == 1 { "/".into() } else { pct(eff) });
        }
        t.row(cells);
    }
    t.print();
    t.write_csv("table7_scaling").unwrap();

    println!("\nshape checks (paper Table VII):");
    for &(name, rate) in &measured {
        let e256 = model.efficiency(bytes_per_proc, rate, 256);
        let e1024 = model.efficiency(bytes_per_proc, rate, 1024);
        println!("  {name}: eff(256)={} eff(1024)={}", pct(e256), pct(e1024));
        assert!(e256 > 0.95, "{name}: near-linear speedup to 256 procs");
        assert!(e1024 < e256 && e1024 > 0.75, "{name}: drop at 1024");
    }
    // SZ-LV has the highest aggregate rate at every scale.
    let sz = measured.iter().find(|(n, _)| *n == "sz_lv").unwrap().1;
    for &(name, rate) in &measured {
        if name != "sz_lv" {
            assert!(sz > rate, "SZ-LV must have the best rate (vs {name})");
        }
    }
}
