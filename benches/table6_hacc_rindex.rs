//! Table VI — compression ratios of the R-index attempts on HACC @
//! eb_rel=1e-4 (paper: every R-index variant LOSES overall vs plain
//! SZ-LV because `yy` is approximately sorted; velocity-based R-index
//! helps velocities ~20% but wrecks yy/zz).

use nblc::bench::{f2, Table, EB_REL};
use nblc::compressors::cpc2000::Cpc2000;
use nblc::compressors::sz::Sz;
use nblc::compressors::szrx::SzRx;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::rindex::RIndexSource;
use nblc::snapshot::{FieldCompressor, SnapshotCompressor, FIELD_NAMES};
use nblc::util::stats::value_range;

/// Per-variable ratios of SZ-LV over a (possibly reordered) snapshot.
fn szlv_per_field(s: &nblc::snapshot::Snapshot) -> Vec<f64> {
    (0..6)
        .map(|f| {
            let eb = value_range(&s.fields[f]) * EB_REL;
            let bytes = Sz::lv().compress(&s.fields[f], eb).unwrap().len();
            (s.fields[f].len() * 4) as f64 / bytes as f64
        })
        .collect()
}

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let mut t = Table::new(
        &format!("Table VI: R-index attempts on HACC @ eb_rel=1e-4 (n={})", s.len()),
        &["Field", "CPC2000", "SZ-LV", "SZ-LV+coordR", "SZ-LV+velR", "SZ-LV+bothR"],
    );

    // CPC2000 per-variable: coords share the joint R-index stream (the
    // paper reports the same 7.1 for xx/yy/zz); velocities are separate.
    let cpc = Cpc2000.compress(&s, &Quality::rel(EB_REL)).unwrap();
    let coord_ratio = (s.len() * 3 * 4) as f64 / cpc.fields[0].bytes.len() as f64;
    let cpc_per: Vec<f64> = (0..6)
        .map(|f| {
            if f < 3 {
                coord_ratio
            } else {
                (s.len() * 4) as f64 / cpc.fields[f - 2].bytes.len() as f64
            }
        })
        .collect();

    let plain = szlv_per_field(&s);
    let mut variants = Vec::new();
    for source in [
        RIndexSource::Coordinates,
        RIndexSource::Velocities,
        RIndexSource::Both,
    ] {
        let rx = SzRx {
            source,
            ..SzRx::rx(4096)
        };
        let perm = rx.sort_permutation(&s, EB_REL);
        let sorted = s.permute(&perm).unwrap();
        variants.push(szlv_per_field(&sorted));
    }

    let overall = |per: &[f64]| 6.0 / per.iter().map(|r| 1.0 / r).sum::<f64>();
    for f in 0..6 {
        t.row(vec![
            FIELD_NAMES[f].into(),
            f2(cpc_per[f]),
            f2(plain[f]),
            f2(variants[0][f]),
            f2(variants[1][f]),
            f2(variants[2][f]),
        ]);
    }
    t.row(vec![
        "Overall".into(),
        f2(cpc.compression_ratio()),
        f2(overall(&plain)),
        f2(overall(&variants[0])),
        f2(overall(&variants[1])),
        f2(overall(&variants[2])),
    ]);
    t.print();
    t.write_csv("table6_hacc_rindex").unwrap();

    println!("\nshape checks (paper Table VI):");
    let o_plain = overall(&plain);
    for (i, name) in ["coordR", "velR", "bothR"].iter().enumerate() {
        let o = overall(&variants[i]);
        println!("  SZ-LV+{name}: {:.2} vs plain {:.2}", o, o_plain);
        assert!(
            o < o_plain,
            "R-index must NOT pay off on HACC overall ({name})"
        );
    }
    // Velocity-based R-index should still help the velocity variables.
    let vel_gain: f64 = (3..6).map(|f| variants[1][f] / plain[f]).product::<f64>();
    println!(
        "  velR velocity-variable gain: {:.1}% (paper ~+20%)",
        (vel_gain.powf(1.0 / 3.0) - 1.0) * 100.0
    );
    assert!(o_plain > cpc.compression_ratio(), "SZ-LV must beat CPC2000 on HACC");
}
