//! Micro-benchmarks of the L3 hot paths (the §Perf targets): lattice
//! quantization, Huffman encode/decode, radix sort, Morton interleave,
//! AVLE, DEFLATE, the end-to-end per-field SZ-LV compress / decompress,
//! and the snapshot-level parallel field-plane engine (1 thread vs all
//! cores; byte-identity across budgets is enforced by
//! `tests/parallel_determinism.rs`, not re-checked here). Uses min-of-N
//! timing (robust on a noisy 1-core box). Besides the usual CSV, the
//! engine rows land in a machine-readable `BENCH_hotpath.json` (codec,
//! threads, MB/s) so later changes have a perf trajectory to compare
//! against.

use nblc::bench::{results_dir, Table, EB_REL};
use nblc::codec::{avle, huffman, lz77};
use nblc::compressors::registry;
use nblc::compressors::sz::Sz;
use nblc::data::DatasetKind;
use nblc::exec::ExecCtx;
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::rindex::morton::interleave3;
use nblc::rindex::sort::sort_perm;
use nblc::snapshot::FieldCompressor;
use nblc::util::rng::Pcg64;
use nblc::util::stats::value_range;
use nblc::util::timer::bench_min_time;
use std::io::Write;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let field = &s.fields[2]; // zz: representative entropy
    let n = field.len();
    let mb = (n * 4) as f64 / 1e6;
    let eb = value_range(field) * EB_REL;
    let quantizer = LatticeQuantizer::new(eb).unwrap();
    let codes = quantizer.quantize(field, Predictor::LastValue);

    let mut t = Table::new(
        &format!("Hot-path micro benches (field n={n}, min-of-3 timing)"),
        &["Stage", "Throughput", "Unit"],
    );

    let tq = bench_min_time(0.5, 3, || quantizer.quantize(field, Predictor::LastValue));
    t.row(vec!["lattice quantize (LV)".into(), format!("{:.1}", mb / tq), "MB/s".into()]);

    let tr = bench_min_time(0.5, 3, || quantizer.reconstruct(&codes));
    t.row(vec!["lattice reconstruct".into(), format!("{:.1}", mb / tr), "MB/s".into()]);

    // Huffman over the real code distribution.
    let radius = 32768i64;
    let symbols: Vec<u32> = codes
        .codes
        .iter()
        .map(|&c| (c.clamp(-radius + 1, radius - 1) + radius) as u32)
        .collect();
    let th = bench_min_time(0.5, 3, || huffman::encode_block(&symbols, 2 * radius as usize + 1).unwrap());
    t.row(vec![
        "huffman encode".into(),
        format!("{:.1}", symbols.len() as f64 / th / 1e6),
        "Msym/s".into(),
    ]);
    let block = huffman::encode_block(&symbols, 2 * radius as usize + 1).unwrap();
    let td = bench_min_time(0.5, 3, || {
        let mut pos = 0;
        huffman::decode_block(&block, &mut pos).unwrap()
    });
    t.row(vec![
        "huffman decode".into(),
        format!("{:.1}", symbols.len() as f64 / td / 1e6),
        "Msym/s".into(),
    ]);

    // Radix sort over realistic Morton keys.
    let mut rng = Pcg64::seeded(1);
    let keys: Vec<u64> = (0..n).map(|_| rng.below(1 << 39)).collect();
    let ts = bench_min_time(0.5, 3, || sort_perm(&keys, 0));
    t.row(vec![
        "radix sort (39-bit keys)".into(),
        format!("{:.1}", n as f64 / ts / 1e6),
        "Mkeys/s".into(),
    ]);

    // Morton interleave.
    let q: Vec<u32> = (0..n).map(|i| (i % (1 << 21)) as u32).collect();
    let tm = bench_min_time(0.3, 3, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= interleave3(q[i], q[(i + 7) % n], q[(i + 13) % n]);
        }
        acc
    });
    t.row(vec![
        "morton interleave3".into(),
        format!("{:.1}", n as f64 / tm / 1e6),
        "Mkeys/s".into(),
    ]);

    // AVLE.
    let deltas: Vec<u64> = (0..n).map(|i| (i % 1000) as u64).collect();
    let ta = bench_min_time(0.3, 3, || avle::encode_all(&deltas));
    t.row(vec![
        "AVLE encode".into(),
        format!("{:.1}", n as f64 / ta / 1e6),
        "Mvals/s".into(),
    ]);

    // DEFLATE on the field bytes.
    let mut raw = Vec::with_capacity(n * 4);
    for &x in field.iter().take(n.min(4 << 20)) {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    let tl = bench_min_time(0.5, 2, || lz77::compress(&raw, lz77::Effort::Fast).unwrap());
    t.row(vec![
        "deflate (fast)".into(),
        format!("{:.1}", raw.len() as f64 / tl / 1e6),
        "MB/s".into(),
    ]);

    // End-to-end SZ-LV field compress / decompress.
    let te = bench_min_time(1.0, 3, || Sz::lv().compress(field, eb).unwrap());
    t.row(vec!["sz_lv compress (e2e)".into(), format!("{:.1}", mb / te), "MB/s".into()]);
    let bytes = Sz::lv().compress(field, eb).unwrap();
    let tdx = bench_min_time(1.0, 3, || Sz::lv().decompress(&bytes).unwrap());
    t.row(vec!["sz_lv decompress (e2e)".into(), format!("{:.1}", mb / tdx), "MB/s".into()]);

    // Snapshot write path (io.rs chunked-buffer reuse): whole-snapshot
    // f32 -> LE bytes -> file throughput.
    let tmp = std::env::temp_dir().join(format!("nblc_hotpath_{}.snap", std::process::id()));
    let tw = bench_min_time(0.5, 3, || nblc::data::io::write_snapshot(&s, &tmp).unwrap());
    std::fs::remove_file(&tmp).ok();
    t.row(vec![
        "snapshot write (io)".into(),
        format!("{:.1}", s.total_bytes() as f64 / tw / 1e6),
        "MB/s".into(),
    ]);

    t.print();
    t.write_csv("hotpath").unwrap();

    // Snapshot-level parallel engine: whole-snapshot compress at 1
    // thread vs all cores, per paper mode. Bytes must not depend on the
    // budget (the engine's determinism contract).
    let n_threads = ExecCtx::auto().threads();
    let total_mb = s.total_bytes() as f64 / 1e6;
    let mut engine = Table::new(
        &format!("Snapshot engine (6 planes, n={}, {} cores)", s.len(), n_threads),
        &["Codec", "Threads", "Compress MB/s", "Speedup"],
    );
    let mut json_rows: Vec<(String, usize, f64)> = Vec::new();
    for spec in ["sz_lv", "sz_lv_rx", "mode:best_compression"] {
        let comp = registry::build_str(spec).unwrap();
        let budgets = if n_threads > 1 { vec![1, n_threads] } else { vec![1] };
        let mut base_rate = 0.0f64;
        for &threads in &budgets {
            let ctx = ExecCtx::with_threads(threads);
            let secs = bench_min_time(1.0, 3, || comp.compress_with(&ctx, &s, EB_REL).unwrap());
            let rate = total_mb / secs;
            if threads == 1 {
                base_rate = rate;
            }
            engine.row(vec![
                spec.into(),
                format!("{threads}"),
                format!("{rate:.1}"),
                format!("{:.2}x", rate / base_rate),
            ]);
            json_rows.push((spec.to_string(), threads, rate));
        }
        // Byte-identity across budgets is enforced by the test suite
        // (tests/parallel_determinism.rs); no redundant smoke here.
    }
    engine.print();
    engine.write_csv("hotpath_engine").unwrap();

    let json_path = results_dir().join("BENCH_hotpath.json");
    let mut j = String::from("[\n");
    for (i, (codec, threads, rate)) in json_rows.iter().enumerate() {
        let sep = if i + 1 == json_rows.len() { "" } else { "," };
        j.push_str(&format!(
            "  {{\"codec\": \"{codec}\", \"threads\": {threads}, \"mb_per_s\": {rate:.2}}}{sep}\n"
        ));
    }
    j.push_str("]\n");
    let mut f = std::fs::File::create(&json_path).unwrap();
    f.write_all(j.as_bytes()).unwrap();
    println!("\nwrote {}", json_path.display());
}
