//! Micro-benchmarks of the L3 hot paths (the §Perf targets): lattice
//! quantization, Huffman encode/decode, radix sort, Morton interleave,
//! AVLE, DEFLATE, the end-to-end per-field SZ-LV compress / decompress,
//! the kernel-backend matrix (every hot loop through every selectable
//! scalar/SIMD table, with a bytes/cycle roofline summary), and the
//! snapshot-level parallel field-plane engine (1 thread vs all
//! cores; byte-identity across budgets and backends is enforced by
//! `tests/parallel_determinism.rs` / `tests/backend_equivalence.rs`,
//! not re-checked here), plus the temporal stream paths (keyframe
//! compress, delta residual compress, mid-chain `decode_timestep`
//! seek). Uses min-of-N
//! timing (robust on a noisy 1-core box). Besides the usual CSV, the
//! engine rows land in a machine-readable `BENCH_hotpath.json` (codec,
//! threads, MB/s) so later changes have a perf trajectory to compare
//! against.

use nblc::bench::{results_dir, Table, BENCH_SEED, EB_REL};
use nblc::codec::{avle, huffman, lz77};
use nblc::compressors::registry;
use nblc::compressors::sz::Sz;
use nblc::coordinator::pipeline::{
    run_insitu, run_insitu_stream, InsituConfig, Sink, SpatialInsitu, StreamConfig,
};
use nblc::coordinator::spatial::plan_spatial;
use nblc::data::archive::{decode_region, decode_shards, Region, ShardReader};
use nblc::data::gen_cosmo::{self, CosmoConfig};
use nblc::data::DatasetKind;
use nblc::exec::ExecCtx;
use nblc::kernels::Kernels;
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::quality::{snapshot_field_stats, Quality, SnapshotStats};
use nblc::temporal::{delta_bounds, predict, residual, residual_quality, TemporalConfig};
use nblc::rindex::morton::{interleave3, interleave_fields_with, quantize_uniform_with};
use nblc::rindex::sort::{segmented_sort_perm_with, sort_perm};
use nblc::snapshot::FieldCompressor;
use nblc::util::bits::{BitReader, BitWriter};
use nblc::util::rng::Pcg64;
use nblc::util::stats::value_range;
use nblc::util::timer::bench_min_time;
use std::io::Write;

/// Time `work` at 1 thread vs all cores: one table row per budget
/// (rate + speedup vs the 1-thread base) and one machine-readable
/// `(json_label, threads, MB/s)` row for `BENCH_hotpath.json`. Shared
/// by the compress-engine and archive-decode scaling benches so the
/// row/JSON shape can't drift between them.
fn bench_scaling(
    table: &mut Table,
    json_rows: &mut Vec<(String, usize, f64)>,
    n_threads: usize,
    total_mb: f64,
    row_label: &str,
    json_label: &str,
    mut work: impl FnMut(&ExecCtx),
) {
    let budgets = if n_threads > 1 { vec![1, n_threads] } else { vec![1] };
    // (Scaling rows run on the selected kernel backend; the per-backend
    // matrix below isolates the kernel contribution at threads=1.)
    let mut base_rate = 0.0f64;
    for &threads in &budgets {
        let ctx = ExecCtx::with_threads(threads);
        let secs = bench_min_time(1.0, 3, || work(&ctx));
        let rate = total_mb / secs;
        if threads == 1 {
            base_rate = rate;
        }
        table.row(vec![
            row_label.into(),
            format!("{threads}"),
            format!("{rate:.1}"),
            format!("{:.2}x", rate / base_rate),
        ]);
        json_rows.push((json_label.to_string(), threads, rate));
    }
}

/// Time one vectorized hot loop through every selectable kernel table
/// (threads = 1, so only the instruction mix differs). One table row
/// per backend plus a machine-readable `stage:backend` JSON row, and
/// the raw rates are collected for the roofline summary.
#[allow(clippy::too_many_arguments)]
fn bench_kernel_stage(
    table: &mut Table,
    json_rows: &mut Vec<(String, usize, f64)>,
    roofline: &mut Vec<(&'static str, Vec<(&'static str, f64)>)>,
    variants: &[&'static Kernels],
    ghz: f64,
    name: &'static str,
    data_mb: f64,
    mut work: impl FnMut(&'static Kernels),
) {
    let mut rates = Vec::new();
    let mut scalar_rate = 0.0f64;
    for &kern in variants {
        let secs = bench_min_time(0.3, 3, || work(kern));
        let rate = data_mb / secs;
        if kern.label == "scalar" {
            scalar_rate = rate;
        }
        let speedup = if scalar_rate > 0.0 { rate / scalar_rate } else { 1.0 };
        table.row(vec![
            name.into(),
            kern.label.into(),
            format!("{rate:.1}"),
            format!("{speedup:.2}x"),
            format!("{:.2}", rate / (ghz * 1e3)),
        ]);
        json_rows.push((format!("{name}:{}", kern.label), 1, rate));
        rates.push((kern.label, rate));
    }
    roofline.push((name, rates));
}

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let field = &s.fields[2]; // zz: representative entropy
    let n = field.len();
    let mb = (n * 4) as f64 / 1e6;
    let eb = value_range(field) * EB_REL;
    let quantizer = LatticeQuantizer::new(eb).unwrap();
    let codes = quantizer.quantize(field, Predictor::LastValue);

    let mut t = Table::new(
        &format!("Hot-path micro benches (field n={n}, min-of-3 timing)"),
        &["Stage", "Throughput", "Unit"],
    );
    // Single-thread micro rows land in BENCH_hotpath.json too (threads
    // = 1), so the CI regression gate can pin inner-loop throughputs.
    let mut json_rows: Vec<(String, usize, f64)> = Vec::new();

    // Quantize: split (chunked branchless two-pass, the shipping path)
    // vs fused (the legacy inline predict+verify loop, kept as the
    // behavioral reference).
    let tq = bench_min_time(0.5, 3, || quantizer.quantize(field, Predictor::LastValue));
    t.row(vec!["lattice quantize (LV, split)".into(), format!("{:.1}", mb / tq), "MB/s".into()]);
    let tq_ref = bench_min_time(0.5, 3, || {
        quantizer.quantize_reference(field, Predictor::LastValue, true)
    });
    t.row(vec![
        "lattice quantize (LV, fused legacy)".into(),
        format!("{:.1}", mb / tq_ref),
        "MB/s".into(),
    ]);
    json_rows.push(("quantize_split".into(), 1, mb / tq));
    json_rows.push(("quantize_fused_legacy".into(), 1, mb / tq_ref));

    let tr = bench_min_time(0.5, 3, || quantizer.reconstruct(&codes));
    t.row(vec!["lattice reconstruct".into(), format!("{:.1}", mb / tr), "MB/s".into()]);

    // Huffman over the real code distribution.
    let radius = 32768i64;
    let symbols: Vec<u32> = codes
        .codes
        .iter()
        .map(|&c| (c.clamp(-radius + 1, radius - 1) + radius) as u32)
        .collect();
    let th = bench_min_time(0.5, 3, || huffman::encode_block(&symbols, 2 * radius as usize + 1).unwrap());
    t.row(vec![
        "huffman encode".into(),
        format!("{:.1}", symbols.len() as f64 / th / 1e6),
        "Msym/s".into(),
    ]);
    let block = huffman::encode_block(&symbols, 2 * radius as usize + 1).unwrap();
    let td = bench_min_time(0.5, 3, || {
        let mut pos = 0;
        huffman::decode_block(&block, &mut pos).unwrap()
    });
    t.row(vec![
        "huffman decode".into(),
        format!("{:.1}", symbols.len() as f64 / td / 1e6),
        "Msym/s".into(),
    ]);

    // Entropy inner loops, batched vs legacy (same bytes either way;
    // JSON rates in MB/s of u32 symbol data, 4 bytes/symbol, so the
    // gate compares like units across rows).
    let mut counts = vec![0u64; 2 * radius as usize + 1];
    for &s in &symbols {
        counts[s as usize] += 1;
    }
    let enc = huffman::HuffmanEncoder::from_counts(&counts).unwrap();
    let sym_mb = (symbols.len() * 4) as f64 / 1e6;
    let te_batched = bench_min_time(0.5, 3, || {
        let mut w = BitWriter::with_capacity(symbols.len() / 2);
        enc.encode_slice(&mut w, &symbols);
        w.finish()
    });
    let te_legacy = bench_min_time(0.5, 3, || {
        let mut w = BitWriter::with_capacity(symbols.len() / 2);
        for &s in &symbols {
            enc.put(&mut w, s);
        }
        w.finish()
    });
    t.row(vec![
        "huffman emit (batched pairs)".into(),
        format!("{:.1}", symbols.len() as f64 / te_batched / 1e6),
        "Msym/s".into(),
    ]);
    t.row(vec![
        "huffman emit (legacy put)".into(),
        format!("{:.1}", symbols.len() as f64 / te_legacy / 1e6),
        "Msym/s".into(),
    ]);
    json_rows.push(("huffman_encode_batched".into(), 1, sym_mb / te_batched));
    json_rows.push(("huffman_encode_legacy".into(), 1, sym_mb / te_legacy));

    let payload = {
        let mut w = BitWriter::with_capacity(symbols.len() / 2);
        enc.encode_slice(&mut w, &symbols);
        w.finish()
    };
    let dec = huffman::HuffmanDecoder::from_lengths(enc.lengths()).unwrap();
    let td_multi = bench_min_time(0.5, 3, || {
        let mut r = BitReader::new(&payload);
        let mut acc = 0u64;
        dec.decode_all(&mut r, symbols.len(), |s| {
            acc ^= s as u64;
            Ok(())
        })
        .unwrap();
        acc
    });
    let td_legacy = bench_min_time(0.5, 3, || {
        let mut r = BitReader::new(&payload);
        let mut acc = 0u64;
        for _ in 0..symbols.len() {
            acc ^= dec.get(&mut r).unwrap() as u64;
        }
        acc
    });
    t.row(vec![
        "huffman decode (multi-symbol)".into(),
        format!("{:.1}", symbols.len() as f64 / td_multi / 1e6),
        "Msym/s".into(),
    ]);
    t.row(vec![
        "huffman decode (legacy get)".into(),
        format!("{:.1}", symbols.len() as f64 / td_legacy / 1e6),
        "Msym/s".into(),
    ]);
    json_rows.push(("huffman_decode_multisym".into(), 1, sym_mb / td_multi));
    json_rows.push(("huffman_decode_legacy".into(), 1, sym_mb / td_legacy));

    // Radix sort over realistic Morton keys.
    let mut rng = Pcg64::seeded(1);
    let keys: Vec<u64> = (0..n).map(|_| rng.below(1 << 39)).collect();
    let ts = bench_min_time(0.5, 3, || sort_perm(&keys, 0));
    t.row(vec![
        "radix sort (39-bit keys)".into(),
        format!("{:.1}", n as f64 / ts / 1e6),
        "Mkeys/s".into(),
    ]);

    // Morton interleave.
    let q: Vec<u32> = (0..n).map(|i| (i % (1 << 21)) as u32).collect();
    let tm = bench_min_time(0.3, 3, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= interleave3(q[i], q[(i + 7) % n], q[(i + 13) % n]);
        }
        acc
    });
    t.row(vec![
        "morton interleave3".into(),
        format!("{:.1}", n as f64 / tm / 1e6),
        "Mkeys/s".into(),
    ]);

    // AVLE.
    let deltas: Vec<u64> = (0..n).map(|i| (i % 1000) as u64).collect();
    let ta = bench_min_time(0.3, 3, || avle::encode_all(&deltas));
    t.row(vec![
        "AVLE encode".into(),
        format!("{:.1}", n as f64 / ta / 1e6),
        "Mvals/s".into(),
    ]);

    // DEFLATE on the field bytes.
    let mut raw = Vec::with_capacity(n * 4);
    for &x in field.iter().take(n.min(4 << 20)) {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    let tl = bench_min_time(0.5, 2, || lz77::compress(&raw, lz77::Effort::Fast).unwrap());
    t.row(vec![
        "deflate (fast)".into(),
        format!("{:.1}", raw.len() as f64 / tl / 1e6),
        "MB/s".into(),
    ]);

    // End-to-end SZ-LV field compress / decompress.
    let te = bench_min_time(1.0, 3, || Sz::lv().compress(field, eb).unwrap());
    t.row(vec!["sz_lv compress (e2e)".into(), format!("{:.1}", mb / te), "MB/s".into()]);
    let bytes = Sz::lv().compress(field, eb).unwrap();
    let tdx = bench_min_time(1.0, 3, || Sz::lv().decompress(&bytes).unwrap());
    t.row(vec!["sz_lv decompress (e2e)".into(), format!("{:.1}", mb / tdx), "MB/s".into()]);

    // Snapshot write path (io.rs chunked-buffer reuse): whole-snapshot
    // f32 -> LE bytes -> file throughput.
    let tmp = std::env::temp_dir().join(format!("nblc_hotpath_{}.snap", std::process::id()));
    let tw = bench_min_time(0.5, 3, || nblc::data::io::write_snapshot(&s, &tmp).unwrap());
    std::fs::remove_file(&tmp).ok();
    t.row(vec![
        "snapshot write (io)".into(),
        format!("{:.1}", s.total_bytes() as f64 / tw / 1e6),
        "MB/s".into(),
    ]);

    // Planning stage (stats sampling + sample-compress plan): the
    // whole point of a cheap plan is that it costs a negligible
    // fraction of a real compress, so measure both and report the
    // overhead percentage. The JSON row records the plan throughput in
    // MB/s of *planned* (full-snapshot) data, so the CI gate can pin
    // it like any other row.
    let plan_quality = Quality::rel(EB_REL);
    let plan_codec = registry::build_str("sz_lv").unwrap();
    let t_plan = bench_min_time(0.5, 3, || {
        let stats = SnapshotStats::collect(&s);
        plan_codec.plan(&stats, &plan_quality).unwrap()
    });
    let t_full = bench_min_time(1.0, 3, || {
        plan_codec
            .compress_with(&ExecCtx::sequential(), &s, &plan_quality)
            .unwrap()
    });
    let total_mb_all = s.total_bytes() as f64 / 1e6;
    let overhead = t_plan / t_full * 100.0;
    t.row(vec![
        "plan (stats + sample compress)".into(),
        format!("{:.1}", total_mb_all / t_plan),
        "MB/s planned".into(),
    ]);
    t.row(vec![
        "plan overhead vs sz_lv compress".into(),
        format!("{overhead:.2}"),
        "% (target < 1%)".into(),
    ]);
    json_rows.push(("plan:sz_lv".into(), 1, total_mb_all / t_plan));
    if overhead >= 1.0 {
        eprintln!("WARNING: plan overhead {overhead:.2}% exceeds the 1% budget");
    }

    t.print();
    t.write_csv("hotpath").unwrap();

    // Kernel-backend matrix: the four vectorized hot loops (quantize
    // round/check, Huffman pair-table emit, Morton key build, radix
    // sort) timed through every table the host can select. Bytes are
    // backend-invariant (tests/backend_equivalence.rs); only throughput
    // may differ. The bytes/cycle column and the roofline summary put
    // the speedups on an absolute scale — set NBLC_CPU_GHZ to your
    // actual clock (default 3.0) for honest numbers.
    let ghz: f64 = std::env::var("NBLC_CPU_GHZ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3.0);
    let variants = Kernels::variants();
    let mut kern_table = Table::new(
        &format!(
            "Kernel backends (selected: {}, {} available, B/cycle at {ghz:.1} GHz)",
            nblc::kernels::active().label,
            variants.len()
        ),
        &["Kernel", "Backend", "MB/s", "Speedup", "B/cycle"],
    );
    let mut roofline: Vec<(&'static str, Vec<(&'static str, f64)>)> = Vec::new();
    bench_kernel_stage(
        &mut kern_table,
        &mut json_rows,
        &mut roofline,
        &variants,
        ghz,
        "quantize",
        mb,
        |kern| {
            LatticeQuantizer::quantize_field_into_with(
                kern,
                eb,
                field,
                Predictor::LastValue,
                Vec::new(),
            )
            .unwrap();
        },
    );
    bench_kernel_stage(
        &mut kern_table,
        &mut json_rows,
        &mut roofline,
        &variants,
        ghz,
        "huffman_encode",
        sym_mb,
        |kern| {
            let mut w = BitWriter::with_capacity(symbols.len() / 2);
            enc.encode_slice_with(kern, &mut w, &symbols);
            w.finish();
        },
    );
    let coord_mb = (n * 3 * 4) as f64 / 1e6;
    bench_kernel_stage(
        &mut kern_table,
        &mut json_rows,
        &mut roofline,
        &variants,
        ghz,
        "morton_key",
        coord_mb,
        |kern| {
            let qx = quantize_uniform_with(kern, &s.fields[0], 16);
            let qy = quantize_uniform_with(kern, &s.fields[1], 16);
            let qz = quantize_uniform_with(kern, &s.fields[2], 16);
            interleave_fields_with(kern, &[&qx, &qy, &qz], 16);
        },
    );
    let key_mb = (n * 8) as f64 / 1e6;
    bench_kernel_stage(
        &mut kern_table,
        &mut json_rows,
        &mut roofline,
        &variants,
        ghz,
        "radix_sort",
        key_mb,
        |kern| {
            segmented_sort_perm_with(kern, &keys, 0, 0);
        },
    );
    kern_table.print();
    kern_table.write_csv("hotpath_kernels").unwrap();
    println!("Roofline @ {ghz:.2} GHz (override with NBLC_CPU_GHZ):");
    for (name, rates) in &roofline {
        let scalar = rates
            .iter()
            .find(|(l, _)| *l == "scalar")
            .map(|&(_, r)| r)
            .unwrap_or(0.0);
        let (best_label, best) = rates
            .iter()
            .filter(|(l, _)| *l != "scalar")
            .fold(("scalar", scalar), |acc, &(l, r)| if r > acc.1 { (l, r) } else { acc });
        println!(
            "  {name:<15} scalar {:5.2} B/c -> {best_label} {:5.2} B/c ({:.2}x)",
            scalar / (ghz * 1e3),
            best / (ghz * 1e3),
            if scalar > 0.0 { best / scalar } else { 1.0 },
        );
    }
    println!();

    // Snapshot-level parallel engine: whole-snapshot compress at 1
    // thread vs all cores, per paper mode. Bytes must not depend on the
    // budget (the engine's determinism contract).
    let n_threads = ExecCtx::auto().threads();
    let total_mb = s.total_bytes() as f64 / 1e6;
    let mut engine = Table::new(
        &format!("Snapshot engine (6 planes, n={}, {} cores)", s.len(), n_threads),
        &["Codec", "Threads", "Compress MB/s", "Speedup"],
    );
    for spec in ["sz_lv", "sz_lv_rx", "mode:best_compression"] {
        let comp = registry::build_str(spec).unwrap();
        // Byte-identity across budgets is enforced by the test suite
        // (tests/parallel_determinism.rs); no redundant smoke here.
        bench_scaling(&mut engine, &mut json_rows, n_threads, total_mb, spec, spec, |ctx| {
            comp.compress_with(ctx, &s, &Quality::rel(EB_REL)).unwrap();
        });
    }
    engine.print();
    engine.write_csv("hotpath_engine").unwrap();

    // Sharded-archive parallel decompression: pipeline-write a v3
    // archive, then decode it end-to-end at 1 thread vs all cores. The
    // shard fan-out is what makes DECODE scale with cores (compression
    // already scales via pipeline workers / field planes).
    let decode_shard_count = 8usize;
    let arch_spec = registry::canonical("sz_lv").unwrap();
    let arch_path = std::env::temp_dir().join(format!("nblc_hotpath_{}.nblc", std::process::id()));
    run_insitu(
        &s,
        &InsituConfig {
            shards: decode_shard_count,
            layout: None,
            workers: n_threads.clamp(1, decode_shard_count),
            threads: 1,
            queue_depth: 4,
            quality: Quality::rel(EB_REL),
            factory: registry::factory(&arch_spec).unwrap(),
            sink: Sink::Archive {
                path: arch_path.clone(),
                spec: arch_spec.clone(),
            },
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    let reader = ShardReader::open(&arch_path).unwrap();
    let mut decode = Table::new(
        &format!("v3 archive decode ({decode_shard_count} shards, shard fan-out)"),
        &["Stage", "Threads", "Decode MB/s", "Speedup"],
    );
    bench_scaling(
        &mut decode,
        &mut json_rows,
        n_threads,
        total_mb,
        "v3 shard decode (sz_lv)",
        "v3_decode:sz_lv",
        |ctx| {
            decode_shards(&reader, reader.spec(), None, ctx).unwrap();
        },
    );
    decode.print();
    decode.write_csv("hotpath_decode").unwrap();

    // Spatially-pruned region reads: the same small box query against a
    // Morton-layout archive (footer bbox index decodes only overlapping
    // shards) and against a cost-layout archive (full scan + filter
    // fallback). Rates are effective scan throughput — archive MB per
    // query second — so the pruned row's win IS the pruning ratio.
    let region_shards = 16usize;
    let plan = plan_spatial(&s, region_shards, 10, &ExecCtx::sequential()).unwrap();
    let spatial_path =
        std::env::temp_dir().join(format!("nblc_hotpath_spatial_{}.nblc", std::process::id()));
    let cost_path =
        std::env::temp_dir().join(format!("nblc_hotpath_cost_{}.nblc", std::process::id()));
    run_insitu(
        &plan.snapshot,
        &InsituConfig {
            shards: region_shards,
            layout: Some(plan.layout.clone()),
            workers: n_threads.clamp(1, region_shards),
            threads: 1,
            queue_depth: 4,
            quality: Quality::rel(EB_REL),
            factory: registry::factory(&arch_spec).unwrap(),
            sink: Sink::Archive {
                path: spatial_path.clone(),
                spec: arch_spec.clone(),
            },
            spatial: Some(SpatialInsitu {
                bits: plan.bits,
                seg: 2_048,
                keys: std::sync::Arc::clone(&plan.keys),
            }),
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    run_insitu(
        &s,
        &InsituConfig {
            shards: region_shards,
            layout: None,
            workers: n_threads.clamp(1, region_shards),
            threads: 1,
            queue_depth: 4,
            quality: Quality::rel(EB_REL),
            factory: registry::factory(&arch_spec).unwrap(),
            sink: Sink::Archive {
                path: cost_path.clone(),
                spec: arch_spec.clone(),
            },
            spatial: None,
            max_retries: 0,
            sink_fault: None,
        },
    )
    .unwrap();
    let sp_reader = ShardReader::open(&spatial_path).unwrap();
    let cost_reader = ShardReader::open(&cost_path).unwrap();
    let spb = sp_reader.spatial().expect("spatial archive must carry a footer index");
    // A quarter-extent box around the middle shard's bbox center.
    let mid = sp_reader
        .index()
        .entries
        .iter()
        .enumerate()
        .filter(|(_, e)| e.start < e.end)
        .map(|(i, _)| i)
        .nth(region_shards / 2)
        .unwrap_or(0);
    let bb = &spb.shards[mid].bbox;
    let region = Region::new(
        [
            bb[0] + (bb[1] - bb[0]) * 0.25,
            bb[2] + (bb[3] - bb[2]) * 0.25,
            bb[4] + (bb[5] - bb[4]) * 0.25,
        ],
        [
            bb[1] - (bb[1] - bb[0]) * 0.25,
            bb[3] - (bb[3] - bb[2]) * 0.25,
            bb[5] - (bb[5] - bb[4]) * 0.25,
        ],
    )
    .unwrap();
    let ctx1 = ExecCtx::sequential();
    let probe = decode_region(&sp_reader, sp_reader.spec(), &region, &ctx1).unwrap();
    assert!(probe.indexed, "region bench must run against the footer index");
    assert!(
        probe.shards_touched <= region_shards / 2,
        "interior box touched {} of {region_shards} shards — pruning is broken",
        probe.shards_touched
    );
    let t_pruned = bench_min_time(1.0, 3, || {
        decode_region(&sp_reader, sp_reader.spec(), &region, &ctx1).unwrap();
    });
    let t_full = bench_min_time(1.0, 3, || {
        decode_region(&cost_reader, cost_reader.spec(), &region, &ctx1).unwrap();
    });
    let mut region_t = Table::new(
        &format!(
            "Region decode ({region_shards} shards; box touched {} shards, pruned {})",
            probe.shards_touched, probe.shards_pruned
        ),
        &["Stage", "Threads", "Effective MB/s", "Speedup"],
    );
    region_t.row(vec![
        "region decode (full scan)".into(),
        "1".into(),
        format!("{:.1}", total_mb / t_full),
        "1.00x".into(),
    ]);
    region_t.row(vec![
        "region decode (index pruned)".into(),
        "1".into(),
        format!("{:.1}", total_mb / t_pruned),
        format!("{:.2}x", t_full / t_pruned),
    ]);
    region_t.print();
    region_t.write_csv("hotpath_region").unwrap();
    json_rows.push(("region_decode:full".into(), 1, total_mb / t_full));
    json_rows.push(("region_decode:pruned".into(), 1, total_mb / t_pruned));
    if t_pruned >= t_full {
        eprintln!(
            "WARNING: pruned region decode ({t_pruned:.4}s) is not faster than the full scan ({t_full:.4}s)"
        );
    }
    std::fs::remove_file(&spatial_path).ok();
    std::fs::remove_file(&cost_path).ok();

    // Serve daemon over the same archive: cold = first full get (cache
    // empty, pays decode + wire), hot = repeated gets once every shard
    // is resident (pure cache + wire). The hot row is the one worth
    // gating — it pins the service overhead on top of decode.
    let serve_handle = nblc::serve::Server::bind(
        &nblc::serve::ServeConfig {
            addr: "127.0.0.1:0".into(),
            cache_mb: 1024,
            max_inflight: 4,
            queue_timeout_ms: 10_000,
            decode_budget_ms: 0,
            threads: n_threads,
        },
        &[&arch_path],
    )
    .unwrap()
    .spawn();
    let serve_addr = serve_handle.addr();
    let mut serve = Table::new(
        "Serve daemon (loopback, full-archive gets)",
        &["Stage", "Threads", "MB/s", "Speedup"],
    );
    let get_all = || {
        let mut client = nblc::serve::ServeClient::connect(serve_addr).unwrap();
        match client.get("", None).unwrap() {
            nblc::serve::GetReply::Data(d) => d,
            nblc::serve::GetReply::Busy(_) => panic!("bench daemon shed a request"),
        }
    };
    let t_cold = {
        let timer = nblc::util::timer::Timer::start();
        let d = get_all();
        let secs = timer.secs();
        assert_eq!(d.cache_hits, 0, "cold get must decode every shard");
        secs
    };
    serve.row(vec![
        "serve get (cold cache)".into(),
        "1".into(),
        format!("{:.1}", total_mb / t_cold),
        "1.00x".into(),
    ]);
    json_rows.push(("serve_get_cold".into(), 1, total_mb / t_cold));
    let t_hot = bench_min_time(1.0, 3, || {
        let d = get_all();
        assert!(d.cache_hits > 0, "hot get must be served from cache");
    });
    serve.row(vec![
        "serve get (hot cache)".into(),
        "1".into(),
        format!("{:.1}", total_mb / t_hot),
        format!("{:.2}x", t_cold / t_hot),
    ]);
    json_rows.push(("serve_get_hot".into(), 1, total_mb / t_hot));
    serve.print();
    serve.write_csv("hotpath_serve").unwrap();
    serve_handle.stop();
    std::fs::remove_file(&arch_path).ok();

    // Temporal stream hot paths: keyframe compress (a plain bounded
    // snapshot compress), delta-step compress (predict from *decoded*
    // state + residual + margin-bound compress — the per-step work of
    // `run_insitu_stream`), and the mid-chain `decode_timestep` seek
    // (keyframe decode + replayed delta steps). Rates are MB/s of one
    // timestep's raw planes; the last column pins why the delta path
    // exists — residuals of a velocity-coherent stream compress far
    // smaller than keyframes.
    let n_t = (n / 4).clamp(10_000, 250_000);
    let t_steps = 8usize;
    let t_interval = 4usize;
    let dt = 0.05;
    let tseries = gen_cosmo::time_series(
        &CosmoConfig {
            n_particles: n_t,
            seed: BENCH_SEED,
            ..Default::default()
        },
        t_steps,
        dt,
    );
    let slab_mb = (n_t * 6 * 4) as f64 / 1e6;
    let kf_q = Quality::rel(EB_REL);
    let t_comp = registry::build_str("sz_lv").unwrap();
    let t_kf = bench_min_time(0.5, 3, || {
        t_comp.compress_with(&ctx1, &tseries[4], &kf_q).unwrap()
    });
    let kf_bundle = t_comp.compress_with(&ctx1, &tseries[4], &kf_q).unwrap();
    let prev_dec = t_comp.decompress_with(&ctx1, &kf_bundle).unwrap();
    let t5_stats = snapshot_field_stats(&tseries[5]);
    let step_bounds = delta_bounds(&kf_q.resolve_fields(&t5_stats), &t5_stats);
    let res_q = residual_quality(&step_bounds);
    let delta_work = || {
        let pred = predict(&prev_dec, dt);
        let res = residual(&tseries[5], &pred, &step_bounds).unwrap();
        t_comp.compress_with(&ctx1, &res, &res_q).unwrap()
    };
    let t_delta = bench_min_time(0.5, 3, || delta_work());
    let delta_bundle = delta_work();
    let dvk = kf_bundle.compressed_bytes() as f64 / delta_bundle.compressed_bytes() as f64;
    // Seek: one stream archive written outside the timing, then a
    // mid-chain decode (t = 6 replays keyframe 4 plus two deltas).
    let stream_path =
        std::env::temp_dir().join(format!("nblc_hotpath_stream_{}.nblc", std::process::id()));
    let stream_report = run_insitu_stream(
        &tseries,
        &StreamConfig {
            shards: 4,
            threads: 1,
            quality: kf_q.clone(),
            factory: registry::factory(&arch_spec).unwrap(),
            path: stream_path.clone(),
            spec: arch_spec.clone(),
            temporal: TemporalConfig::new(t_interval).unwrap(),
            dt,
            max_retries: 0,
        },
    )
    .unwrap();
    let stream_reader = ShardReader::open(&stream_path).unwrap();
    let seek_probe = stream_reader.decode_timestep(6, &ctx1).unwrap();
    assert_eq!(seek_probe.keyframe, 4, "mid-chain seek must replay from keyframe 4");
    let t_seek = bench_min_time(0.5, 3, || {
        stream_reader.decode_timestep(6, &ctx1).unwrap();
    });
    let mut temporal_t = Table::new(
        &format!("Temporal stream (n={n_t}/step, K={t_interval}, {t_steps} steps, sz_lv)"),
        &["Stage", "Threads", "MB/s", "Bytes vs keyframe"],
    );
    temporal_t.row(vec![
        "keyframe compress".into(),
        "1".into(),
        format!("{:.1}", slab_mb / t_kf),
        "1.00x".into(),
    ]);
    temporal_t.row(vec![
        "delta compress (predict+residual)".into(),
        "1".into(),
        format!("{:.1}", slab_mb / t_delta),
        format!("{dvk:.2}x smaller"),
    ]);
    temporal_t.row(vec![
        "mid-chain seek (t=6, depth 2)".into(),
        "1".into(),
        format!("{:.1}", slab_mb / t_seek),
        "-".into(),
    ]);
    temporal_t.print();
    temporal_t.write_csv("hotpath_temporal").unwrap();
    json_rows.push(("temporal:keyframe".into(), 1, slab_mb / t_kf));
    json_rows.push(("temporal:delta".into(), 1, slab_mb / t_delta));
    json_rows.push(("temporal:seek".into(), 1, slab_mb / t_seek));
    if let Some(r) = stream_report.delta_vs_keyframe() {
        println!("temporal: archive delta steps {r:.2}x smaller than keyframes");
        if r < 1.5 {
            eprintln!("WARNING: delta steps only {r:.2}x smaller than keyframes (target >= 1.5x)");
        }
    }
    std::fs::remove_file(&stream_path).ok();

    let json_path = results_dir().join("BENCH_hotpath.json");
    let mut j = String::from("[\n");
    for (i, (codec, threads, rate)) in json_rows.iter().enumerate() {
        let sep = if i + 1 == json_rows.len() { "" } else { "," };
        j.push_str(&format!(
            "  {{\"codec\": \"{codec}\", \"threads\": {threads}, \"mb_per_s\": {rate:.2}}}{sep}\n"
        ));
    }
    j.push_str("]\n");
    let mut f = std::fs::File::create(&json_path).unwrap();
    f.write_all(j.as_bytes()).unwrap();
    println!("\nwrote {}", json_path.display());
}
