//! Micro-benchmarks of the L3 hot paths (the §Perf targets): lattice
//! quantization, Huffman encode/decode, radix sort, Morton interleave,
//! AVLE, DEFLATE, and the end-to-end per-field SZ-LV compress /
//! decompress. Uses min-of-N timing (robust on a noisy 1-core box).

use nblc::bench::{Table, EB_REL};
use nblc::codec::{avle, huffman, lz77};
use nblc::compressors::sz::Sz;
use nblc::data::DatasetKind;
use nblc::model::quant::{LatticeQuantizer, Predictor};
use nblc::rindex::morton::interleave3;
use nblc::rindex::sort::sort_perm;
use nblc::snapshot::FieldCompressor;
use nblc::util::rng::Pcg64;
use nblc::util::stats::value_range;
use nblc::util::timer::bench_min_time;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let field = &s.fields[2]; // zz: representative entropy
    let n = field.len();
    let mb = (n * 4) as f64 / 1e6;
    let eb = value_range(field) * EB_REL;
    let quantizer = LatticeQuantizer::new(eb).unwrap();
    let codes = quantizer.quantize(field, Predictor::LastValue);

    let mut t = Table::new(
        &format!("Hot-path micro benches (field n={n}, min-of-3 timing)"),
        &["Stage", "Throughput", "Unit"],
    );

    let tq = bench_min_time(0.5, 3, || quantizer.quantize(field, Predictor::LastValue));
    t.row(vec!["lattice quantize (LV)".into(), format!("{:.1}", mb / tq), "MB/s".into()]);

    let tr = bench_min_time(0.5, 3, || quantizer.reconstruct(&codes));
    t.row(vec!["lattice reconstruct".into(), format!("{:.1}", mb / tr), "MB/s".into()]);

    // Huffman over the real code distribution.
    let radius = 32768i64;
    let symbols: Vec<u32> = codes
        .codes
        .iter()
        .map(|&c| (c.clamp(-radius + 1, radius - 1) + radius) as u32)
        .collect();
    let th = bench_min_time(0.5, 3, || huffman::encode_block(&symbols, 2 * radius as usize + 1).unwrap());
    t.row(vec![
        "huffman encode".into(),
        format!("{:.1}", symbols.len() as f64 / th / 1e6),
        "Msym/s".into(),
    ]);
    let block = huffman::encode_block(&symbols, 2 * radius as usize + 1).unwrap();
    let td = bench_min_time(0.5, 3, || {
        let mut pos = 0;
        huffman::decode_block(&block, &mut pos).unwrap()
    });
    t.row(vec![
        "huffman decode".into(),
        format!("{:.1}", symbols.len() as f64 / td / 1e6),
        "Msym/s".into(),
    ]);

    // Radix sort over realistic Morton keys.
    let mut rng = Pcg64::seeded(1);
    let keys: Vec<u64> = (0..n).map(|_| rng.below(1 << 39)).collect();
    let ts = bench_min_time(0.5, 3, || sort_perm(&keys, 0));
    t.row(vec![
        "radix sort (39-bit keys)".into(),
        format!("{:.1}", n as f64 / ts / 1e6),
        "Mkeys/s".into(),
    ]);

    // Morton interleave.
    let q: Vec<u32> = (0..n).map(|i| (i % (1 << 21)) as u32).collect();
    let tm = bench_min_time(0.3, 3, || {
        let mut acc = 0u64;
        for i in 0..n {
            acc ^= interleave3(q[i], q[(i + 7) % n], q[(i + 13) % n]);
        }
        acc
    });
    t.row(vec![
        "morton interleave3".into(),
        format!("{:.1}", n as f64 / tm / 1e6),
        "Mkeys/s".into(),
    ]);

    // AVLE.
    let deltas: Vec<u64> = (0..n).map(|i| (i % 1000) as u64).collect();
    let ta = bench_min_time(0.3, 3, || avle::encode_all(&deltas));
    t.row(vec![
        "AVLE encode".into(),
        format!("{:.1}", n as f64 / ta / 1e6),
        "Mvals/s".into(),
    ]);

    // DEFLATE on the field bytes.
    let mut raw = Vec::with_capacity(n * 4);
    for &x in field.iter().take(n.min(4 << 20)) {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    let tl = bench_min_time(0.5, 2, || lz77::compress(&raw, lz77::Effort::Fast).unwrap());
    t.row(vec![
        "deflate (fast)".into(),
        format!("{:.1}", raw.len() as f64 / tl / 1e6),
        "MB/s".into(),
    ]);

    // End-to-end SZ-LV field compress / decompress.
    let te = bench_min_time(1.0, 3, || Sz::lv().compress(field, eb).unwrap());
    t.row(vec!["sz_lv compress (e2e)".into(), format!("{:.1}", mb / te), "MB/s".into()]);
    let bytes = Sz::lv().compress(field, eb).unwrap();
    let tdx = bench_min_time(1.0, 3, || Sz::lv().decompress(&bytes).unwrap());
    t.row(vec!["sz_lv decompress (e2e)".into(), format!("{:.1}", mb / tdx), "MB/s".into()]);

    // Snapshot write path (io.rs chunked-buffer reuse): whole-snapshot
    // f32 -> LE bytes -> file throughput.
    let tmp = std::env::temp_dir().join(format!("nblc_hotpath_{}.snap", std::process::id()));
    let tw = bench_min_time(0.5, 3, || nblc::data::io::write_snapshot(&s, &tmp).unwrap());
    std::fs::remove_file(&tmp).ok();
    t.row(vec![
        "snapshot write (io)".into(),
        format!("{:.1}", s.total_bytes() as f64 / tw / 1e6),
        "MB/s".into(),
    ]);

    t.print();
    t.write_csv("hotpath").unwrap();
}
