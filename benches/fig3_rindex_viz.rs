//! Figs. 2-3 — R-index construction and the before/after visualization
//! of coordinate variables under R-index sorting. Emits the plot series
//! as CSV (`results/fig3_before.csv`, `results/fig3_after.csv`) and
//! prints smoothness statistics.

use nblc::bench::{f2, Table};
use nblc::data::DatasetKind;
use nblc::rindex::sort::sort_perm;
use nblc::rindex::{build_rindex, RIndexSource};
use nblc::util::stats::autocorrelation;
use std::io::Write;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Amdf);
    let window = 4096.min(s.len());
    let sub = s.slice(0, window);
    let keys = build_rindex(&sub, RIndexSource::Coordinates, 13);
    let perm = sort_perm(&keys, 0);
    let sorted = sub.permute(&perm).unwrap();

    let dir = nblc::bench::results_dir();
    for (name, snap) in [("fig3_before", &sub), ("fig3_after", &sorted)] {
        let mut f = std::fs::File::create(dir.join(format!("{name}.csv"))).unwrap();
        writeln!(f, "idx,xx,yy,zz").unwrap();
        for i in 0..window {
            writeln!(
                f,
                "{i},{},{},{}",
                snap.fields[0][i], snap.fields[1][i], snap.fields[2][i]
            )
            .unwrap();
        }
    }

    let mut t = Table::new(
        "Fig. 3: coordinate smoothness before/after R-index sorting (AMDF window)",
        &["Field", "ac1 before", "ac1 after", "mean |diff| before", "mean |diff| after"],
    );
    for f in 0..3 {
        let mean_step = |xs: &[f32]| {
            xs.windows(2).map(|w| (w[1] - w[0]).abs() as f64).sum::<f64>() / (xs.len() - 1) as f64
        };
        let before = mean_step(&sub.fields[f]);
        let after = mean_step(&sorted.fields[f]);
        t.row(vec![
            nblc::snapshot::FIELD_NAMES[f].into(),
            f2(autocorrelation(&sub.fields[f], 1)),
            f2(autocorrelation(&sorted.fields[f], 1)),
            f2(before),
            f2(after),
        ]);
        assert!(
            after < before,
            "sorting must smooth the reordered data (paper Fig. 3)"
        );
    }
    t.print();
    println!("\nCSV series written to results/fig3_before.csv / fig3_after.csv");
}
