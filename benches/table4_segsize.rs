//! Table IV — SZ-LV + R-index sorting with different segment sizes on
//! AMDF @ eb_rel=1e-4 (paper: ratio 2.85 -> 3.03..3.20 as segments grow
//! 1024 -> 16384; rate drops from 94.4 to ~35 MB/s).

use nblc::bench::{f1, f2, Table, EB_REL};
use nblc::compressors::registry;
use nblc::compressors::sz::Sz;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::snapshot::{PerField, SnapshotCompressor};
use nblc::util::timer::time_it;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Amdf);
    let mb = s.total_bytes() as f64 / 1e6;
    let mut t = Table::new(
        &format!("Table IV: SZ-LV-RX segment-size sweep on AMDF (n={})", s.len()),
        &["Method", "Segment", "Ratio", "Rate (MB/s)"],
    );
    let (plain, secs) = time_it(|| PerField(Sz::lv()).compress(&s, &Quality::rel(EB_REL)).unwrap());
    let plain_ratio = plain.compression_ratio();
    t.row(vec!["SZ-LV".into(), "/".into(), f2(plain_ratio), f1(mb / secs)]);
    let mut last_ratio = 0.0;
    for seg in [1024usize, 2048, 4096, 8192, 16384] {
        // The Table IV sweep, expressed as parameterized codec specs.
        let comp = registry::build_str(&format!("sz_lv_rx:segment={seg}")).unwrap();
        let (bundle, secs) = time_it(|| comp.compress(&s, &Quality::rel(EB_REL)).unwrap());
        let ratio = bundle.compression_ratio();
        t.row(vec![
            "SZ-LV-RX".into(),
            format!("{seg}"),
            f2(ratio),
            f1(mb / secs),
        ]);
        assert!(ratio > plain_ratio, "RX must improve over plain SZ-LV");
        last_ratio = ratio;
    }
    t.print();
    t.write_csv("table4_segsize").unwrap();
    println!(
        "\nshape check: RX(16384) ratio {} > SZ-LV {} (paper: 3.20 vs 2.85) OK",
        f2(last_ratio),
        f2(plain_ratio)
    );
}
