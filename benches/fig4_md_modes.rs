//! Fig. 4 — compression ratios AND rates of all methods on AMDF @
//! eb_rel=1e-4, defining the three modes (paper: SZ-LV best rate at
//! −12% ratio vs CPC2000; SZ-LV-PRX ≈2x CPC2000's rate at equal ratio;
//! SZ-CPC2000 +13% ratio, +10% rate vs CPC2000).

use nblc::bench::{f1, f2, Table, EB_REL};
use nblc::compressors::registry;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::util::timer::bench_min_time;

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Amdf);
    let mb = s.total_bytes() as f64 / 1e6;
    let mut t = Table::new(
        &format!("Fig. 4: ratio & rate on AMDF @ eb_rel=1e-4 (n={})", s.len()),
        &["Method", "Ratio", "Rate (MB/s)", "Mode"],
    );
    let mode_of = |name: &str| match name {
        "sz_lv" => "best_speed",
        "sz_lv_prx" => "best_tradeoff",
        "sz_cpc2000" => "best_compression",
        _ => "",
    };
    let mut results = Vec::new();
    for name in ["fpzip", "zfp", "sz", "cpc2000", "sz_lv", "sz_lv_rx", "sz_lv_prx", "sz_cpc2000"] {
        let comp = registry::build_str(name).unwrap();
        let q = Quality::rel(EB_REL);
        let bundle = comp.compress(&s, &q).unwrap();
        let secs = bench_min_time(0.5, 2, || comp.compress(&s, &q).unwrap());
        let ratio = bundle.compression_ratio();
        let rate = mb / secs;
        results.push((name, ratio, rate));
        t.row(vec![name.into(), f2(ratio), f1(rate), mode_of(name).into()]);
    }
    t.print();
    t.write_csv("fig4_md_modes").unwrap();

    let get = |n: &str| results.iter().find(|(name, _, _)| *name == n).unwrap();
    let (_, r_cpc, v_cpc) = get("cpc2000");
    let (_, r_lv, v_lv) = get("sz_lv");
    let (_, r_szcpc, _) = get("sz_cpc2000");
    println!("\nshape checks (paper Fig. 4):");
    println!(
        "  SZ-LV rate {:.0} MB/s vs CPC2000 {:.0} MB/s ({}x; paper 4.4x)",
        v_lv, v_cpc, f2(v_lv / v_cpc)
    );
    println!(
        "  SZ-LV ratio {:.2} vs CPC2000 {:.2} ({:+.1}%; paper -12%)",
        r_lv, r_cpc, (r_lv / r_cpc - 1.0) * 100.0
    );
    println!(
        "  SZ-CPC2000 ratio {:.2} vs CPC2000 {:.2} ({:+.1}%; paper +13%)",
        r_szcpc, r_cpc, (r_szcpc / r_cpc - 1.0) * 100.0
    );
    assert!(r_lv < r_cpc, "CPC2000 must out-compress SZ-LV on AMDF");
    assert!(r_szcpc > r_cpc, "SZ-CPC2000 must out-compress CPC2000");
    assert!(v_lv > v_cpc, "SZ-LV must out-run CPC2000");
}
