//! Ablations beyond the paper's tables:
//! 1. PJRT-backed vs native quantizer on the SZ-LV hot path (the cost
//!    of the AOT/PJRT bridge on CPU; skipped when artifacts are absent);
//! 2. SZ's optional lossless backend (Huffman-only vs +DEFLATE);
//! 3. pipeline queue-depth (backpressure) sweep;
//! 4. scheduler routing on/off on cosmology data (the §V-C rule).

use nblc::bench::{f1, f2, Table, EB_REL};
use nblc::compressors::sz::{LzMode, Sz, SzConfig};
use nblc::compressors::{mode_compressor, registry, Mode};
use nblc::coordinator::pipeline::{run_insitu, InsituConfig, Sink};
use nblc::coordinator::choose_compressor;
use nblc::data::DatasetKind;
use nblc::quality::Quality;
use nblc::snapshot::FieldCompressor;
use nblc::util::stats::value_range;
use nblc::util::timer::time_it;
use std::sync::Arc;

fn main() {
    let hacc = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let field = &hacc.fields[2];
    let eb = value_range(field) * EB_REL;
    let mb = (field.len() * 4) as f64 / 1e6;

    // 1. PJRT vs native quantizer.
    let mut t1 = Table::new(
        "Ablation 1: native vs PJRT quantizer (SZ-LV, one HACC field)",
        &["Path", "Rate (MB/s)", "Ratio"],
    );
    let (native_bytes, native_secs) = time_it(|| Sz::lv().compress(field, eb).unwrap());
    t1.row(vec![
        "native (f64 lattice)".into(),
        f1(mb / native_secs),
        f2((field.len() * 4) as f64 / native_bytes.len() as f64),
    ]);
    match nblc::runtime::Runtime::load_default() {
        Some(rt) => {
            let sz_pjrt = nblc::runtime::quantizer::SzPjrt::lv(Arc::new(rt));
            // Warm up (compile path already done at load; first exec warms buffers).
            let _ = sz_pjrt.compress(&field[..65536.min(field.len())], eb).unwrap();
            let (bytes, secs) = time_it(|| sz_pjrt.compress(field, eb).unwrap());
            t1.row(vec![
                "pjrt (AOT Pallas kernel)".into(),
                f1(mb / secs),
                f2((field.len() * 4) as f64 / bytes.len() as f64),
            ]);
            println!(
                "stream sizes: native {} vs pjrt {} bytes (must be within 1%)",
                native_bytes.len(),
                bytes.len()
            );
            assert!(
                (native_bytes.len() as f64 - bytes.len() as f64).abs()
                    < native_bytes.len() as f64 * 0.01
            );
        }
        None => println!("(PJRT ablation skipped: artifacts/ not built)"),
    }
    t1.print();

    // 2. Lossless backend on/off.
    let mut t2 = Table::new(
        "Ablation 2: SZ lossless backend (Huffman only vs +DEFLATE)",
        &["Config", "Ratio", "Rate (MB/s)"],
    );
    for (label, lz) in [
        ("huffman only", LzMode::Off),
        ("huffman + deflate (gated)", LzMode::Fast),
    ] {
        let sz = Sz {
            cfg: SzConfig {
                lz,
                ..Default::default()
            },
        };
        let (bytes, secs) = time_it(|| sz.compress(field, eb).unwrap());
        t2.row(vec![
            label.into(),
            f2((field.len() * 4) as f64 / bytes.len() as f64),
            f1(mb / secs),
        ]);
    }
    t2.print();

    // 3. Queue depth sweep (backpressure cost).
    let mut t3 = Table::new(
        "Ablation 3: pipeline queue depth (64 shards, model sink)",
        &["Queue depth", "Wall (s)", "Source stalls", "Ratio"],
    );
    for depth in [1usize, 2, 8, 32] {
        let factory = registry::factory("sz_lv").unwrap();
        let report = run_insitu(
            &hacc,
            &InsituConfig {
                shards: 64,
                layout: None,
                workers: 1,
                threads: 1,
                queue_depth: depth,
                quality: Quality::rel(EB_REL),
                factory,
                sink: Sink::Null,
            },
        )
        .unwrap();
        t3.row(vec![
            format!("{depth}"),
            format!("{:.2}", report.wall_secs),
            format!("{}", report.source_stalls),
            f2(report.ratio),
        ]);
    }
    t3.print();

    // 4. Scheduler routing on cosmology data.
    let mut t4 = Table::new(
        "Ablation 4: scheduler routing (par.V-C rule) on HACC",
        &["Requested", "Executed", "Ratio"],
    );
    for req in [Mode::BestCompression, Mode::BestSpeed] {
        let routed = choose_compressor(&hacc, req);
        let ratio = mode_compressor(routed)
            .compress(&hacc, &Quality::rel(EB_REL))
            .unwrap()
            .compression_ratio();
        t4.row(vec![req.name().into(), routed.name().into(), f2(ratio)]);
    }
    let unrouted = mode_compressor(Mode::BestCompression)
        .compress(&hacc, &Quality::rel(EB_REL))
        .unwrap()
        .compression_ratio();
    t4.row(vec![
        "best_compression (routing off)".into(),
        "best_compression".into(),
        f2(unrouted),
    ]);
    t4.print();
}
