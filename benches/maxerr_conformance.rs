//! §VI max-error conformance — the paper's observations:
//! * CPC2000 / SZ / SZ-LV / SZ-LV-PRX / SZ-CPC2000: max error equals
//!   the user bound (never exceeds it);
//! * ZFP over-preserves (max err 0.32..0.46x the bound at 1e-4);
//! * FPZIP (fixed 21 retained bits) lands NEAR the bound and may exceed
//!   it (paper: 0.6e-4..2.4e-4 at eb_rel 1e-4).

use nblc::bench::{sci, Table, EB_REL};
use nblc::compressors::registry;
use nblc::compressors::cpc2000::Cpc2000;
use nblc::compressors::szcpc::SzCpc2000;
use nblc::compressors::szrx::SzRx;
use nblc::data::DatasetKind;
use nblc::metrics::ErrorStats;
use nblc::quality::Quality;
use nblc::snapshot::Snapshot;

fn max_rel_err(orig: &Snapshot, recon: &Snapshot) -> f64 {
    let ranges = orig.ranges();
    (0..6)
        .map(|f| {
            let s = ErrorStats::compute(&orig.fields[f], &recon.fields[f]).unwrap();
            s.max_err / ranges[f].max(1e-30)
        })
        .fold(0.0, f64::max)
}

fn main() {
    let s = nblc::bench::bench_snapshot(DatasetKind::Amdf);
    let mut t = Table::new(
        &format!("Max-error conformance @ eb_rel=1e-4 (AMDF n={})", s.len()),
        &["Method", "max rel err", "vs bound", "verdict"],
    );
    for name in ["cpc2000", "zfp", "sz", "sz_lv", "sz_lv_prx", "sz_cpc2000", "fpzip"] {
        let comp = registry::build_str(name).unwrap();
        let bundle = comp.compress(&s, &Quality::rel(EB_REL)).unwrap();
        let recon = comp.decompress(&bundle).unwrap();
        // Reordering methods: align with their deterministic permutation.
        let reference = match name {
            "cpc2000" => s.permute(&Cpc2000.sort_permutation(&s, EB_REL).unwrap()).unwrap(),
            "sz_cpc2000" => s
                .permute(&SzCpc2000::default().sort_permutation(&s, EB_REL).unwrap())
                .unwrap(),
            "sz_lv_prx" => s.permute(&SzRx::prx().sort_permutation(&s, EB_REL)).unwrap(),
            _ => s.clone(),
        };
        let max_rel = max_rel_err(&reference, &recon);
        let frac = max_rel / EB_REL;
        let verdict = if name == "zfp" {
            assert!(frac < 1.0, "zfp must over-preserve");
            "over-preserves (paper: 0.32-0.46x)"
        } else if name == "fpzip" {
            assert!(frac > 0.3 && frac < 5.0, "fpzip lands near the bound, frac={frac}");
            "near bound, may exceed (paper: 0.6-2.4x)"
        } else {
            assert!(frac <= 1.0 + 1e-9, "{name} must respect the bound, frac={frac}");
            "exactly bounded"
        };
        t.row(vec![name.into(), sci(max_rel), format!("{frac:.2}x"), verdict.into()]);
    }
    t.print();
    t.write_csv("maxerr_conformance").unwrap();
}
