//! Table II — compression ratios of the state-of-the-art lossless and
//! lossy compressors on both data sets under eb_rel = 1e-4.
//!
//! Paper values: GZIP 1.2/1.1, CPC2000 3.5/3.2, FPZIP 3.1/1.8,
//! ISABELA 1.4/1.2, ZFP 2.3/1.9, SZ 4.6/2.7 (HACC/AMDF). The shape to
//! reproduce: SZ best on HACC, CPC2000 best on AMDF, GZIP/ISABELA at
//! the bottom.

use nblc::bench::{f2, Table, EB_REL};
use nblc::compressors::{registry, table2_lineup};
use nblc::data::DatasetKind;
use nblc::quality::Quality;

fn main() {
    let paper: &[(&str, f64, f64)] = &[
        ("gzip", 1.2, 1.1),
        ("cpc2000", 3.5, 3.2),
        ("fpzip", 3.1, 1.8),
        ("isabela", 1.4, 1.2),
        ("zfp", 2.3, 1.9),
        ("sz", 4.6, 2.7),
    ];
    let hacc = nblc::bench::bench_snapshot(DatasetKind::Hacc);
    let amdf = nblc::bench::bench_snapshot(DatasetKind::Amdf);
    let mut t = Table::new(
        &format!(
            "Table II: compression ratios @ eb_rel=1e-4 (HACC n={}, AMDF n={})",
            hacc.len(),
            amdf.len()
        ),
        &["Compressor", "HACC", "AMDF", "HACC(paper)", "AMDF(paper)"],
    );
    for name in table2_lineup() {
        let comp = registry::build_str(name).unwrap();
        let rh = comp
            .compress(&hacc, &Quality::rel(EB_REL))
            .map(|b| b.compression_ratio())
            .unwrap_or(f64::NAN);
        let ra = comp
            .compress(&amdf, &Quality::rel(EB_REL))
            .map(|b| b.compression_ratio())
            .unwrap_or(f64::NAN);
        let (ph, pa) = paper
            .iter()
            .find(|(n, _, _)| *n == name)
            .map(|&(_, h, a)| (h, a))
            .unwrap();
        t.row(vec![name.into(), f2(rh), f2(ra), f2(ph), f2(pa)]);
    }
    t.print();
    t.write_csv("table2_ratios").unwrap();
}
